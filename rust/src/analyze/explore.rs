//! Small-scope model checker for the victim-selection protocol.
//!
//! Exhaustively explores every interleaving of N pages × M frames × K
//! warps against a [`ResidencyPolicy`]'s `Take`/`WaitOn`/`GiveUp`
//! victim protocol, looking for deadlock cycles (WaitOn graphs with no
//! Take exit), livelock, reference-count leaks, and contract violations
//! (a demand fault answered `GiveUp`, or a `Take` of an unusable slot).
//! The scope is deliberately tiny — the small-scope hypothesis: protocol
//! bugs in this class show up at a handful of pages and frames, and at
//! that size the whole state space fits in memory.
//!
//! ## The model
//!
//! The abstraction of `gpuvm/runtime.rs`'s frames universe:
//!
//! - **Frames** are `Free`, `Filling(page)`, or `Resident{page, refs}`,
//!   each with a FIFO queue of parked demand faults (`WaitOn` targets).
//! - **Warps** run fixed scripts of page-set accesses. Executing an op
//!   releases the previous op's references (the paper's reference
//!   counters), then touches its pages in ascending order: resident →
//!   take a reference; filling/parked → join (coalesced fault);
//!   unmapped → query the policy. `Take(f)` evicts `f`'s resident page
//!   (if any) and starts the fill; `WaitOn(f)` parks the fault behind
//!   `f`. A warp with unfilled pages blocks; its references pin their
//!   frames — the hold-then-wait ingredient every deadlock needs.
//! - **Fill completion** (one nondeterministic transition per in-flight
//!   fill) makes the frame resident and wakes joiners.
//! - **Parked service**: a frame that is free or has drained to zero
//!   references starts the fill for its oldest parked fault. The model
//!   services *liberally* (whenever eligible, as its own transition), so
//!   a model deadlock is a genuine wait-cycle among blocked warps — a
//!   protocol property — not a missed-wakeup artifact of one runtime's
//!   event plumbing.
//!
//! The usable-slot oracle matches the runtime's `usable_frame`: free or
//! resident-unreferenced, and no parked waiters. Policy decision state
//! forks via [`ResidencyPolicy::clone_box`] and deduplicates via
//! [`ResidencyPolicy::state_sig`], making `pick_victim` a checkable
//! transition relation over `(frames, warps, policy)` states.
//!
//! Exploration is breadth-first, so the first deadlock found comes with
//! a minimal repro schedule; the wait cycle is extracted from the
//! terminal state's warp → frame → holder edges. Livelock is checked by
//! reverse reachability from the all-done terminals (structurally it
//! cannot occur — every non-access transition strictly shrinks the
//! pending-fill measure — but the checker verifies rather than trusts).

use super::protocol::ProtocolFamily;
use crate::residency::{
    build, ResidencyPolicy, ResidencyPolicyKind, Slot, Universe, VictimChoice, VictimQuery,
};
use crate::util::fxhash::{FxHashMap, FxHasher};
use anyhow::Result;
use std::collections::{BTreeSet, VecDeque};
use std::hash::Hasher;

/// Model seed for the `random` engine's probe stream (the only
/// nondeterminism a policy owns). Fixed so certification is a stable,
/// reproducible statement: "at this scope and seed, the state space
/// contains no deadlock".
pub const MODEL_SEED: u64 = 0x6b75_766d;

/// Visited-state cap; past this the verdict is `Inconclusive` rather
/// than a false certificate.
const MAX_STATES: usize = 250_000;

/// Exploration scope: the N×M×K in "small scope".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scope {
    pub pages: usize,
    pub frames: usize,
    pub warps: usize,
}

impl Default for Scope {
    /// The certified default: 4 pages × 3 frames × 2 warps — above the
    /// ISSUE floor (3×2×2), oversubscribed (pages > frames), and small
    /// enough to explore exhaustively for every policy.
    fn default() -> Self {
        Scope {
            pages: 4,
            frames: 3,
            warps: 2,
        }
    }
}

impl Scope {
    fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.frames >= 2, "scope needs >= 2 frames");
        anyhow::ensure!(self.warps >= 1, "scope needs >= 1 warp");
        anyhow::ensure!(
            self.pages > self.frames,
            "scope needs pages > frames (no oversubscription, nothing to evict)"
        );
        Ok(())
    }

    fn label(&self) -> String {
        format!("{}p x {}f x {}w", self.pages, self.frames, self.warps)
    }
}

/// A located deadlock: the wait cycle plus the shortest schedule that
/// reaches it (BFS order ⇒ minimal).
#[derive(Debug, Clone)]
pub struct DeadlockReport {
    /// Human-readable wait-cycle edges (warp → frame → holder → …).
    pub cycle: Vec<String>,
    /// Transition labels from the initial state to the deadlock.
    pub schedule: Vec<String>,
}

/// Model-check outcome for one policy.
#[derive(Debug, Clone)]
pub enum Verdict {
    /// Every reachable terminal completes all warps with all reference
    /// counts drained.
    DeadlockFree { terminals: usize },
    Deadlock(DeadlockReport),
    /// Some reachable state cannot reach any all-done terminal.
    Livelock {
        trapped: usize,
        schedule: Vec<String>,
    },
    /// An all-done terminal left a non-zero reference count.
    RefcountLeak {
        detail: String,
        schedule: Vec<String>,
    },
    /// The policy broke the victim-protocol contract (demand `GiveUp`,
    /// or `Take` of an unusable slot).
    ContractViolation {
        detail: String,
        schedule: Vec<String>,
    },
    /// State cap hit before the space was exhausted.
    Inconclusive { explored: usize },
}

/// One policy's certification result.
#[derive(Debug, Clone)]
pub struct CheckResult {
    pub policy: ResidencyPolicyKind,
    pub scope: Scope,
    pub seed: u64,
    /// Distinct states explored.
    pub states: usize,
    pub verdict: Verdict,
}

impl CheckResult {
    /// The expected certification outcome: `fifo-strict` deadlocks at
    /// the default scope (the certified finding — see
    /// `residency/fifo.rs`); every other policy is deadlock-free *at
    /// the default scope*. Certification is scope-bounded:
    /// `fifo-refcount` genuinely deadlocks at 5 pages × 3 frames × 3
    /// warps (three warps each pin a frame and fault on a fourth page
    /// — reference priority has nothing left to skip), so away from
    /// the default scope both FIFO variants may legitimately report
    /// either verdict and only the *other* five policies are still
    /// required to be deadlock-free.
    pub fn expected(&self) -> bool {
        let scope_bounded = matches!(
            self.policy,
            ResidencyPolicyKind::FifoStrict | ResidencyPolicyKind::FifoRefcount
        );
        if scope_bounded && self.scope != Scope::default() {
            // Larger scopes may or may not exhibit the wedge; both
            // outcomes are legitimate explorations.
            return matches!(
                self.verdict,
                Verdict::Deadlock(_) | Verdict::DeadlockFree { .. }
            );
        }
        if self.policy == ResidencyPolicyKind::FifoStrict {
            matches!(self.verdict, Verdict::Deadlock(_))
        } else {
            matches!(self.verdict, Verdict::DeadlockFree { .. })
        }
    }

    /// Render for terminal / CI-artifact output.
    pub fn render(&self) -> String {
        let mut s = format!("{:<16} @ {}: ", self.policy.name(), self.scope.label());
        match &self.verdict {
            Verdict::DeadlockFree { terminals } => {
                s.push_str(&format!(
                    "deadlock-free ({} states, {terminals} terminals, no livelock, no refcount leak)\n",
                    self.states
                ));
            }
            Verdict::Deadlock(d) => {
                s.push_str(&format!(
                    "DEADLOCK after {} steps ({} states explored)\n  wait cycle:\n",
                    d.schedule.len(),
                    self.states
                ));
                for edge in &d.cycle {
                    s.push_str(&format!("    {edge}\n"));
                }
                s.push_str("  minimal repro schedule:\n");
                for (i, step) in d.schedule.iter().enumerate() {
                    s.push_str(&format!("    {}. {step}\n", i + 1));
                }
            }
            Verdict::Livelock { trapped, schedule } => {
                s.push_str(&format!(
                    "LIVELOCK: {trapped} states cannot reach completion; e.g. after:\n"
                ));
                for (i, step) in schedule.iter().enumerate() {
                    s.push_str(&format!("    {}. {step}\n", i + 1));
                }
            }
            Verdict::RefcountLeak { detail, schedule } => {
                s.push_str(&format!("REFCOUNT LEAK: {detail}; schedule:\n"));
                for (i, step) in schedule.iter().enumerate() {
                    s.push_str(&format!("    {}. {step}\n", i + 1));
                }
            }
            Verdict::ContractViolation { detail, schedule } => {
                s.push_str(&format!("CONTRACT VIOLATION: {detail}; schedule:\n"));
                for (i, step) in schedule.iter().enumerate() {
                    s.push_str(&format!("    {}. {step}\n", i + 1));
                }
            }
            Verdict::Inconclusive { explored } => {
                s.push_str(&format!("inconclusive: state cap hit after {explored} states\n"));
            }
        }
        s
    }
}

#[derive(Clone, PartialEq, Eq)]
enum FrameSt {
    Free,
    Filling(u64),
    Resident { page: u64, refs: u32 },
}

#[derive(Clone)]
struct Frame {
    st: FrameSt,
    /// Demand faults parked behind this frame (`WaitOn`), FIFO.
    parked: VecDeque<u64>,
}

#[derive(Clone)]
struct Warp {
    next_op: usize,
    /// Pages of the current op still being filled; non-empty = blocked.
    missing: BTreeSet<u64>,
    /// Frames referenced by the current op, released when the next op
    /// starts (or on retirement).
    holds: Vec<usize>,
}

struct ModelState {
    frames: Vec<Frame>,
    warps: Vec<Warp>,
    policy: Box<dyn ResidencyPolicy>,
}

impl Clone for ModelState {
    fn clone(&self) -> Self {
        ModelState {
            frames: self.frames.clone(),
            warps: self.warps.clone(),
            policy: self.policy.clone_box(),
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum Move {
    Exec(usize),
    Fill(usize),
    Service(usize),
}

/// Per-warp access scripts for a scope. Warp 0 runs the hold-then-fault
/// shape every deadlock needs — fault p0, then touch p0 (keeping its
/// reference) while faulting p1. The remaining pages round-robin over
/// the other warps as single-page ops, generating the cross-traffic
/// that forces evictions.
fn scripts(scope: &Scope) -> Vec<Vec<Vec<u64>>> {
    let mut s: Vec<Vec<Vec<u64>>> = vec![vec![vec![0], vec![0, 1]]];
    for _ in 1..scope.warps {
        s.push(Vec::new());
    }
    for (i, p) in (2..scope.pages as u64).enumerate() {
        let w = if scope.warps > 1 {
            1 + i % (scope.warps - 1)
        } else {
            0
        };
        s[w].push(vec![p]);
    }
    s
}

fn usable(frames: &[Frame], f: Slot) -> bool {
    let fr = &frames[f as usize];
    fr.parked.is_empty() && matches!(fr.st, FrameSt::Free | FrameSt::Resident { refs: 0, .. })
}

fn frame_holding(frames: &[Frame], page: u64) -> Option<usize> {
    frames.iter().position(|fr| match fr.st {
        FrameSt::Filling(p) | FrameSt::Resident { page: p, .. } => p == page,
        FrameSt::Free => false,
    })
}

/// Release one warp's holds, draining reference counts.
fn release_holds(frames: &mut [Frame], policy: &mut dyn ResidencyPolicy, warp: &mut Warp) {
    for &f in &warp.holds {
        if let FrameSt::Resident { refs, .. } = &mut frames[f].st {
            *refs -= 1;
            if *refs == 0 {
                policy.on_drain(0, f as Slot);
            }
        }
    }
    warp.holds.clear();
}

/// Start filling `page` on frame `f`, evicting resident content.
fn begin_fill(frames: &mut [Frame], policy: &mut dyn ResidencyPolicy, f: usize, page: u64) {
    if matches!(frames[f].st, FrameSt::Resident { .. }) {
        policy.on_evict(0, f as Slot);
    }
    frames[f].st = FrameSt::Filling(page);
    policy.on_fill(0, f as Slot, page, false);
}

/// Apply one move; `Err` carries a contract-violation description.
fn apply(
    state: &mut ModelState,
    scripts: &[Vec<Vec<u64>>],
    mv: Move,
) -> std::result::Result<(), String> {
    match mv {
        Move::Exec(w) => {
            let op_idx = state.warps[w].next_op;
            state.warps[w].next_op += 1;
            {
                let warp = &mut state.warps[w];
                release_holds(&mut state.frames, state.policy.as_mut(), warp);
            }
            let op = &scripts[w][op_idx];
            for &p in op {
                if let Some(f) = frame_holding(&state.frames, p) {
                    match &mut state.frames[f].st {
                        FrameSt::Resident { refs, .. } => {
                            *refs += 1;
                            state.warps[w].holds.push(f);
                            state.policy.on_touch(0, f as Slot);
                        }
                        FrameSt::Filling(_) => {
                            // Join the in-flight fill; the completion
                            // hands out the reference.
                            state.warps[w].missing.insert(p);
                        }
                        FrameSt::Free => unreachable!("frame_holding never returns Free"),
                    }
                    continue;
                }
                if state.frames.iter().any(|fr| fr.parked.contains(&p)) {
                    // Coalesce with the already-parked fault.
                    state.warps[w].missing.insert(p);
                    continue;
                }
                // Demand fault: ask the policy for a victim.
                let choice = {
                    let frames = &state.frames;
                    let oracle = |s: Slot| usable(frames, s);
                    let q = VictimQuery {
                        gpu: 0,
                        demand: true,
                        prefetch_issued: 0,
                        prefetch_accuracy: 0.0,
                        usable: &oracle,
                    };
                    state.policy.pick_victim(&q)
                };
                match choice {
                    VictimChoice::Take(s) => {
                        if !usable(&state.frames, s) {
                            return Err(format!(
                                "policy Take(frame {s}) of an unusable slot for page p{p}"
                            ));
                        }
                        begin_fill(&mut state.frames, state.policy.as_mut(), s as usize, p);
                        state.warps[w].missing.insert(p);
                    }
                    VictimChoice::WaitOn(s) => {
                        state.frames[s as usize].parked.push_back(p);
                        state.warps[w].missing.insert(p);
                    }
                    VictimChoice::GiveUp => {
                        return Err(format!(
                            "policy answered GiveUp to a demand fault for page p{p} \
                             (demand faults must park: Take or WaitOn)"
                        ));
                    }
                }
            }
            if state.warps[w].missing.is_empty() && state.warps[w].next_op == scripts[w].len() {
                // Retired: the runtime's Done step releases immediately.
                let warp = &mut state.warps[w];
                release_holds(&mut state.frames, state.policy.as_mut(), warp);
            }
            Ok(())
        }
        Move::Fill(f) => {
            let FrameSt::Filling(page) = state.frames[f].st else {
                unreachable!("Fill move on a non-filling frame");
            };
            state.frames[f].st = FrameSt::Resident { page, refs: 0 };
            for w in 0..state.warps.len() {
                if state.warps[w].missing.remove(&page) {
                    if let FrameSt::Resident { refs, .. } = &mut state.frames[f].st {
                        *refs += 1;
                    }
                    state.warps[w].holds.push(f);
                    if state.warps[w].missing.is_empty()
                        && state.warps[w].next_op == scripts[w].len()
                    {
                        let warp = &mut state.warps[w];
                        release_holds(&mut state.frames, state.policy.as_mut(), warp);
                    }
                }
            }
            Ok(())
        }
        Move::Service(f) => {
            let page = state.frames[f]
                .parked
                .pop_front()
                .expect("Service move on a frame without parked faults");
            begin_fill(&mut state.frames, state.policy.as_mut(), f, page);
            Ok(())
        }
    }
}

fn enabled_moves(state: &ModelState, scripts: &[Vec<Vec<u64>>]) -> Vec<(Move, String)> {
    let mut out = Vec::new();
    for (w, warp) in state.warps.iter().enumerate() {
        if warp.missing.is_empty() && warp.next_op < scripts[w].len() {
            let pages: Vec<String> = scripts[w][warp.next_op]
                .iter()
                .map(|p| format!("p{p}"))
                .collect();
            out.push((Move::Exec(w), format!("w{w}: access {{{}}}", pages.join(","))));
        }
    }
    for (f, fr) in state.frames.iter().enumerate() {
        match fr.st {
            FrameSt::Filling(p) => {
                out.push((Move::Fill(f), format!("fill of p{p} on frame {f} completes")));
            }
            FrameSt::Free | FrameSt::Resident { .. } => {}
        }
        if !fr.parked.is_empty()
            && matches!(fr.st, FrameSt::Free | FrameSt::Resident { refs: 0, .. })
        {
            let p = fr.parked.front().expect("checked non-empty");
            out.push((Move::Service(f), format!("service parked fault p{p} on frame {f}")));
        }
    }
    out
}

fn all_done(state: &ModelState, scripts: &[Vec<Vec<u64>>]) -> bool {
    state
        .warps
        .iter()
        .enumerate()
        .all(|(w, warp)| warp.missing.is_empty() && warp.next_op == scripts[w].len())
}

fn sig(state: &ModelState) -> u64 {
    let mut v: Vec<u64> = Vec::with_capacity(64);
    for fr in &state.frames {
        match fr.st {
            FrameSt::Free => v.push(0),
            FrameSt::Filling(p) => {
                v.push(1);
                v.push(p);
            }
            FrameSt::Resident { page, refs } => {
                v.push(2);
                v.push(page);
                v.push(u64::from(refs));
            }
        }
        v.push(fr.parked.len() as u64);
        v.extend(fr.parked.iter().copied());
    }
    for warp in &state.warps {
        v.push(3);
        v.push(warp.next_op as u64);
        v.push(warp.missing.len() as u64);
        v.extend(warp.missing.iter().copied());
        let mut holds: Vec<usize> = warp.holds.clone();
        holds.sort_unstable();
        v.push(holds.len() as u64);
        v.extend(holds.iter().map(|&h| h as u64));
    }
    state.policy.state_sig(&mut v);
    let mut h = FxHasher::default();
    for x in v {
        h.write_u64(x);
    }
    h.finish()
}

/// Extract the wait cycle from a deadlocked terminal state: each
/// blocked warp waits on a page parked behind a frame whose references
/// are held by another blocked warp.
fn wait_cycle(state: &ModelState) -> Vec<String> {
    // warp → (page, frame, holder) following first edges; the walk must
    // revisit a warp (the holder of every pinned frame is blocked).
    let next_edge = |w: usize| -> Option<(u64, usize, usize)> {
        let p = *state.warps[w].missing.iter().next()?;
        let f = state.frames.iter().position(|fr| fr.parked.contains(&p))?;
        let holder = state.warps.iter().position(|warp| warp.holds.contains(&f))?;
        Some((p, f, holder))
    };
    let start = match state.warps.iter().position(|w| !w.missing.is_empty()) {
        Some(w) => w,
        None => return vec!["no blocked warp (internal error)".into()],
    };
    let mut seen = vec![false; state.warps.len()];
    let mut path = Vec::new();
    let mut w = start;
    loop {
        if seen[w] {
            break;
        }
        seen[w] = true;
        match next_edge(w) {
            Some((p, f, holder)) => {
                path.push(format!(
                    "w{w} waits for p{p}, parked behind frame {f}; frame {f} is held by w{holder}"
                ));
                w = holder;
            }
            None => {
                path.push(format!(
                    "w{w} blocked, but no parked edge found (in-flight fill pending?)"
                ));
                break;
            }
        }
    }
    path
}

fn schedule_to(parents: &[(usize, String)], idx: usize) -> Vec<String> {
    let mut steps = Vec::new();
    let mut i = idx;
    while i != 0 {
        let (parent, ref label) = parents[i];
        steps.push(label.clone());
        i = parent;
    }
    steps.reverse();
    steps
}

/// Model-check one policy at one scope/seed.
pub fn check_policy(kind: ResidencyPolicyKind, scope: Scope, seed: u64) -> Result<CheckResult> {
    scope.validate()?;
    let scripts = scripts(&scope);
    let initial = ModelState {
        frames: vec![
            Frame {
                st: FrameSt::Free,
                parked: VecDeque::new(),
            };
            scope.frames
        ],
        warps: vec![
            Warp {
                next_op: 0,
                missing: BTreeSet::new(),
                holds: Vec::new(),
            };
            scope.warps
        ],
        policy: build(
            kind,
            Universe::Frames {
                frames_per_gpu: scope.frames,
            },
            1,
            seed,
        ),
    };

    let mut states: Vec<ModelState> = vec![initial];
    // parents[i] = (parent index, transition label); parents[0] unused.
    let mut parents: Vec<(usize, String)> = vec![(0, String::new())];
    let mut index_of: FxHashMap<u64, usize> = FxHashMap::default();
    index_of.insert(sig(&states[0]), 0);
    let mut edges: Vec<Vec<usize>> = vec![Vec::new()];
    let mut terminals: Vec<usize> = Vec::new();
    let mut queue: VecDeque<usize> = VecDeque::new();
    queue.push_back(0);

    let mut verdict: Option<Verdict> = None;
    while let Some(idx) = queue.pop_front() {
        let moves = enabled_moves(&states[idx], &scripts);
        if moves.is_empty() {
            if all_done(&states[idx], &scripts) {
                if let Some(f) = states[idx]
                    .frames
                    .iter()
                    .position(|fr| matches!(fr.st, FrameSt::Resident { refs, .. } if refs > 0))
                {
                    verdict = Some(Verdict::RefcountLeak {
                        detail: format!("frame {f} retains references after all warps retired"),
                        schedule: schedule_to(&parents, idx),
                    });
                    break;
                }
                terminals.push(idx);
            } else {
                verdict = Some(Verdict::Deadlock(DeadlockReport {
                    cycle: wait_cycle(&states[idx]),
                    schedule: schedule_to(&parents, idx),
                }));
                break;
            }
            continue;
        }
        for (mv, label) in moves {
            let mut next = states[idx].clone();
            if let Err(detail) = apply(&mut next, &scripts, mv) {
                let mut schedule = schedule_to(&parents, idx);
                schedule.push(label);
                verdict = Some(Verdict::ContractViolation { detail, schedule });
                break;
            }
            let s = sig(&next);
            match index_of.get(&s) {
                Some(&existing) => edges[idx].push(existing),
                None => {
                    let new_idx = states.len();
                    index_of.insert(s, new_idx);
                    states.push(next);
                    parents.push((idx, label));
                    edges.push(Vec::new());
                    edges[idx].push(new_idx);
                    queue.push_back(new_idx);
                }
            }
        }
        if verdict.is_some() {
            break;
        }
        if states.len() > MAX_STATES {
            verdict = Some(Verdict::Inconclusive {
                explored: states.len(),
            });
            break;
        }
    }

    let verdict = match verdict {
        Some(v) => v,
        None => {
            // Full exploration, no deadlock/leak: check livelock by
            // reverse reachability from the all-done terminals.
            let mut rev: Vec<Vec<usize>> = vec![Vec::new(); states.len()];
            for (from, outs) in edges.iter().enumerate() {
                for &to in outs {
                    rev[to].push(from);
                }
            }
            let mut can_finish = vec![false; states.len()];
            let mut bfs: VecDeque<usize> = terminals.iter().copied().collect();
            for &t in &terminals {
                can_finish[t] = true;
            }
            while let Some(i) = bfs.pop_front() {
                for &p in &rev[i] {
                    if !can_finish[p] {
                        can_finish[p] = true;
                        bfs.push_back(p);
                    }
                }
            }
            let trapped: Vec<usize> = (0..states.len()).filter(|&i| !can_finish[i]).collect();
            if trapped.is_empty() {
                Verdict::DeadlockFree {
                    terminals: terminals.len(),
                }
            } else {
                Verdict::Livelock {
                    trapped: trapped.len(),
                    schedule: schedule_to(&parents, trapped[0]),
                }
            }
        }
    };

    Ok(CheckResult {
        policy: kind,
        scope,
        seed,
        states: states.len(),
        verdict,
    })
}

/// Model-check every registered policy; the certification sweep behind
/// `gpuvm analyze policies` and the CI gate.
pub fn certify_all(scope: Scope, seed: u64) -> Result<Vec<CheckResult>> {
    ResidencyPolicyKind::all()
        .iter()
        .map(|&kind| check_policy(kind, scope, seed))
        .collect()
}

/// The family whose frames-universe protocol the model abstracts.
pub fn modeled_family() -> ProtocolFamily {
    ProtocolFamily::GpuVm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripts_cover_all_pages_once() {
        let s = scripts(&Scope::default());
        assert_eq!(s.len(), 2);
        assert_eq!(s[0], vec![vec![0], vec![0, 1]]);
        let mut others: Vec<u64> = s[1].iter().flatten().copied().collect();
        others.sort_unstable();
        assert_eq!(others, vec![2, 3]);
    }

    #[test]
    fn degenerate_scopes_rejected() {
        let bad = Scope {
            pages: 2,
            frames: 3,
            warps: 2,
        };
        assert!(check_policy(ResidencyPolicyKind::FifoRefcount, bad, MODEL_SEED).is_err());
    }

    #[test]
    fn fifo_strict_deadlocks_at_default_scope() {
        let r = check_policy(ResidencyPolicyKind::FifoStrict, Scope::default(), MODEL_SEED)
            .unwrap();
        let Verdict::Deadlock(d) = &r.verdict else {
            panic!("expected deadlock, got: {}", r.render());
        };
        assert!(!d.schedule.is_empty());
        assert!(!d.cycle.is_empty());
        // The certified shape: a self-cycle through a held frame.
        assert!(
            d.cycle.iter().any(|e| e.contains("held by")),
            "cycle must name the holder: {:?}",
            d.cycle
        );
        assert!(r.expected());
    }

    #[test]
    fn other_six_policies_certify_deadlock_free_at_default_scope() {
        for r in certify_all(Scope::default(), MODEL_SEED).unwrap() {
            if r.policy == ResidencyPolicyKind::FifoStrict {
                continue;
            }
            assert!(
                matches!(r.verdict, Verdict::DeadlockFree { .. }),
                "{}",
                r.render()
            );
            assert!(r.expected());
        }
    }

    #[test]
    fn fifo_refcount_deadlocks_at_the_larger_three_warp_scope() {
        // The PR 6 finding, pinned: reference priority is only
        // deadlock-free at the default scope. With three warps over
        // three frames each warp pins a frame and faults on a fourth
        // page — every frame referenced, nothing left to skip.
        let r = check_policy(
            ResidencyPolicyKind::FifoRefcount,
            Scope {
                pages: 5,
                frames: 3,
                warps: 3,
            },
            MODEL_SEED,
        )
        .unwrap();
        let Verdict::Deadlock(d) = &r.verdict else {
            panic!("expected deadlock, got: {}", r.render());
        };
        assert!(!d.cycle.is_empty());
        // Legitimate at the non-default scope: expected() must not
        // flag it (the CLI certification gate excludes this scope).
        assert!(r.expected(), "{}", r.render());
    }

    #[test]
    fn fifo_strict_survives_without_oversubscribed_reuse() {
        // With warp 0's hold-then-fault shape but frames ample enough
        // to hold the whole working set... pages > frames is required,
        // so instead check a larger frame count still deadlocks or
        // completes without a false positive.
        let r = check_policy(
            ResidencyPolicyKind::FifoStrict,
            Scope {
                pages: 5,
                frames: 4,
                warps: 2,
            },
            MODEL_SEED,
        )
        .unwrap();
        assert!(r.expected(), "{}", r.render());
    }
}
