//! The page-lifecycle protocol as *data*: states, guarded transitions,
//! payload rules, and the violation taxonomy.
//!
//! This is the single source of truth the other two layers consume: the
//! trace linter ([`crate::analyze::lint`]) drives one state machine per
//! `(gpu, page)` through [`step`], and the model checker
//! ([`crate::analyze::explore`]) certifies the victim-selection side of
//! the same protocol. Event payloads follow the per-kind table in the
//! [`crate::trace`] module docs — the linter's [`payload_error`] checks
//! are that table, mechanized.
//!
//! ## States
//!
//! The trace stream exposes five observable per-page states. "Filling"
//! never appears explicitly (fills are recorded at completion, not
//! start), so it is folded into the pending states:
//!
//! - **Unmapped** — not resident, no fill pending.
//! - **Faulted** — a demand fault was recorded; a fill must follow.
//! - **SpecJoined** — GPUVM only: a demand touch joined an in-flight
//!   speculative fill (`promote` recorded; the completion will be a
//!   plain `fill` with no preceding `fault`).
//! - **ResidentSpec** — speculatively filled, never demand-touched.
//! - **Resident** — demand-filled, or speculative and since promoted.
//!
//! ## Family differences
//!
//! The two paged systems share the lifecycle but not every edge:
//!
//! - GPUVM records `promote` both for a demand touch of an
//!   already-resident speculative page *and* for a demand join of an
//!   in-flight speculative fill — so `promote` → `fill` with no `fault`
//!   is legal GPUVM.
//! - UVM's demand join of a speculative pending group is silent: the
//!   completion is recorded as a plain `fill`, so `fill` straight from
//!   **Unmapped** is legal UVM (and illegal GPUVM).
//! - `evict-forced` (unmap under live references) exists only in UVM's
//!   VABlock hammer; GPUVM never force-unmaps.

use crate::trace::TraceEventKind;

/// Which paged system's emission profile a trace must satisfy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProtocolFamily {
    /// GPU-driven UVM (`gpuvm`; also `ideal`, which emits no events and
    /// therefore trivially satisfies the strictest profile).
    GpuVm,
    /// Host-driver UVM (`uvm`, `uvm-memadvise`).
    Uvm,
}

impl ProtocolFamily {
    /// Stable display name.
    pub fn name(self) -> &'static str {
        match self {
            Self::GpuVm => "gpuvm",
            Self::Uvm => "uvm",
        }
    }

    fn bit(self) -> u8 {
        match self {
            Self::GpuVm => FAM_GPUVM,
            Self::Uvm => FAM_UVM,
        }
    }
}

/// Observable per-page lifecycle state (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageState {
    Unmapped,
    Faulted,
    SpecJoined,
    ResidentSpec,
    Resident,
}

impl PageState {
    /// Stable display name.
    pub fn name(self) -> &'static str {
        match self {
            Self::Unmapped => "unmapped",
            Self::Faulted => "faulted",
            Self::SpecJoined => "spec-joined",
            Self::ResidentSpec => "resident-spec",
            Self::Resident => "resident",
        }
    }

    /// Is a page in this state mapped into GPU memory?
    pub fn is_resident(self) -> bool {
        matches!(self, Self::Resident | Self::ResidentSpec)
    }

    /// Is this state waiting on a fill that must eventually arrive?
    pub fn is_pending_fill(self) -> bool {
        matches!(self, Self::Faulted | Self::SpecJoined)
    }
}

/// Family mask bit: edge legal under GPUVM.
pub const FAM_GPUVM: u8 = 1 << 0;
/// Family mask bit: edge legal under UVM.
pub const FAM_UVM: u8 = 1 << 1;
/// Edge legal under both families.
pub const FAM_BOTH: u8 = FAM_GPUVM | FAM_UVM;

/// One guarded transition of the page state machine.
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    pub from: PageState,
    pub on: TraceEventKind,
    pub to: PageState,
    /// Which families admit this edge ([`FAM_GPUVM`] / [`FAM_UVM`]).
    pub families: u8,
    /// Why the edge exists, for violation reports and docs.
    pub note: &'static str,
}

/// The whole per-page protocol, as data. Everything not listed here is
/// an illegal transition.
pub const RULES: &[Rule] = &[
    Rule {
        from: PageState::Unmapped,
        on: TraceEventKind::Fault,
        to: PageState::Faulted,
        families: FAM_BOTH,
        note: "demand fault parks a fill",
    },
    Rule {
        from: PageState::Faulted,
        on: TraceEventKind::Fill,
        to: PageState::Resident,
        families: FAM_BOTH,
        note: "demand fill completes the parked fault",
    },
    Rule {
        from: PageState::Unmapped,
        on: TraceEventKind::SpecFill,
        to: PageState::ResidentSpec,
        families: FAM_BOTH,
        note: "speculative fill with no demand waiter",
    },
    Rule {
        from: PageState::Unmapped,
        on: TraceEventKind::Promote,
        to: PageState::SpecJoined,
        families: FAM_GPUVM,
        note: "demand touch joins an in-flight speculative fill",
    },
    Rule {
        from: PageState::SpecJoined,
        on: TraceEventKind::Fill,
        to: PageState::Resident,
        families: FAM_GPUVM,
        note: "joined speculative fill completes as a demand fill",
    },
    Rule {
        from: PageState::Unmapped,
        on: TraceEventKind::Fill,
        to: PageState::Resident,
        families: FAM_UVM,
        note: "silent demand join of a speculative pending group",
    },
    Rule {
        from: PageState::ResidentSpec,
        on: TraceEventKind::Promote,
        to: PageState::Resident,
        families: FAM_BOTH,
        note: "first demand touch of a resident speculative page",
    },
    Rule {
        from: PageState::Resident,
        on: TraceEventKind::EvictClean,
        to: PageState::Unmapped,
        families: FAM_BOTH,
        note: "clean eviction of a drained page",
    },
    Rule {
        from: PageState::Resident,
        on: TraceEventKind::EvictDirty,
        to: PageState::Unmapped,
        families: FAM_BOTH,
        note: "dirty eviction with write-back",
    },
    Rule {
        from: PageState::ResidentSpec,
        on: TraceEventKind::EvictClean,
        to: PageState::Unmapped,
        families: FAM_BOTH,
        note: "unconsumed speculative fill discarded clean",
    },
    Rule {
        from: PageState::Resident,
        on: TraceEventKind::EvictForced,
        to: PageState::Unmapped,
        families: FAM_UVM,
        note: "UVM VABlock eviction unmaps under live references",
    },
];

/// Look up the transition for `(family, from, on)`; `None` means the
/// event is illegal in that state.
pub fn step(family: ProtocolFamily, from: PageState, on: TraceEventKind) -> Option<&'static Rule> {
    RULES
        .iter()
        .find(|r| r.from == from && r.on == on && r.families & family.bit() != 0)
}

/// Is this event kind an eviction?
pub fn is_evict(kind: TraceEventKind) -> bool {
    matches!(
        kind,
        TraceEventKind::EvictClean | TraceEventKind::EvictDirty | TraceEventKind::EvictForced
    )
}

/// Check an event's payload against the per-kind table in the
/// [`crate::trace`] module docs. Returns a description of the problem,
/// or `None` if the payload is well-formed.
pub fn payload_error(kind: TraceEventKind, page: u64, aux: u64) -> Option<String> {
    match kind {
        TraceEventKind::Fault => {
            (aux > 1).then(|| format!("fault aux must be the write bit (0/1), got {aux}"))
        }
        TraceEventKind::Fill | TraceEventKind::SpecFill => {
            (aux == 0).then(|| format!("{} must carry transferred bytes in aux", kind.name()))
        }
        TraceEventKind::Promote => {
            (aux != 0).then(|| format!("promote carries no payload, got aux={aux}"))
        }
        TraceEventKind::EvictClean => {
            (aux != 0).then(|| format!("evict-clean wrote back {aux} bytes (clean must be 0)"))
        }
        TraceEventKind::EvictDirty => {
            (aux == 0).then(|| "evict-dirty wrote back 0 bytes (that is evict-clean)".to_string())
        }
        // evict-forced may be clean (aux 0) or carry write-back bytes.
        TraceEventKind::EvictForced => None,
        // wr-post aux is `wr_id << 1 | dir`; any value decodes.
        TraceEventKind::WrPost => None,
        TraceEventKind::WrComplete => {
            // `page` carries the completion-queue id (any value is
            // well-formed; UVM's serialized driver always completes on
            // copy queue 0) — per-queue ordering is the happens-before
            // analyzer's job, not a payload shape rule.
            let _ = page;
            (aux & 1 != 0)
                .then(|| format!("wr-complete aux must be wr_id << 1 (bit 0 clear), got {aux}"))
        }
    }
}

/// What a lint or model-check finding violated. Stable names feed
/// reports, tests, and the CI artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ViolationKind {
    /// No rule admits this event in the page's current state.
    IllegalTransition,
    /// An eviction of a page that is not resident (double evict, or
    /// evict of a never-filled page) — the "no use-after-evict /
    /// no double-evict" invariants.
    EvictNonResident,
    /// End of stream with a fault (or speculative join) still pending:
    /// a demand fault that was never filled.
    UnfilledFault,
    /// `wr-complete` for a `wr_id` that was never posted.
    OrphanWrComplete,
    /// Duplicate `wr-complete` for the same `wr_id`: the outstanding-WR
    /// ledger (the reference counter a trace exposes) would go negative.
    NegativeRefcount,
    /// Two `wr-post` events claimed the same `wr_id`.
    DuplicateWrPost,
    /// End of stream with a posted WR never completed.
    UnmatchedWrPost,
    /// Event payload contradicts the per-kind table ([`payload_error`]).
    BadPayload,
}

impl ViolationKind {
    /// Stable report name.
    pub fn name(self) -> &'static str {
        match self {
            Self::IllegalTransition => "illegal-transition",
            Self::EvictNonResident => "evict-non-resident",
            Self::UnfilledFault => "unfilled-fault",
            Self::OrphanWrComplete => "orphan-wr-complete",
            Self::NegativeRefcount => "negative-refcount",
            Self::DuplicateWrPost => "duplicate-wr-post",
            Self::UnmatchedWrPost => "unmatched-wr-post",
            Self::BadPayload => "bad-payload",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_rule_reachable_in_some_family() {
        for r in RULES {
            assert!(r.families & FAM_BOTH != 0, "{r:?} admits no family");
            assert!(!r.note.is_empty());
        }
    }

    #[test]
    fn rules_are_deterministic_per_family() {
        // At most one edge per (family, from, on) triple — `step` relies
        // on first-match being the only match.
        for fam in [ProtocolFamily::GpuVm, ProtocolFamily::Uvm] {
            for a in RULES {
                let dups = RULES
                    .iter()
                    .filter(|b| b.from == a.from && b.on == a.on && b.families & fam.bit() != 0)
                    .count();
                if a.families & fam.bit() != 0 {
                    assert_eq!(dups, 1, "ambiguous edge {a:?} under {}", fam.name());
                }
            }
        }
    }

    #[test]
    fn family_differences() {
        use TraceEventKind as K;
        // UVM admits fill-from-unmapped; GPUVM does not.
        assert!(step(ProtocolFamily::Uvm, PageState::Unmapped, K::Fill).is_some());
        assert!(step(ProtocolFamily::GpuVm, PageState::Unmapped, K::Fill).is_none());
        // GPUVM admits promote-from-unmapped (in-flight join); UVM does not.
        assert!(step(ProtocolFamily::GpuVm, PageState::Unmapped, K::Promote).is_some());
        assert!(step(ProtocolFamily::Uvm, PageState::Unmapped, K::Promote).is_none());
        // Forced eviction is UVM-only.
        assert!(step(ProtocolFamily::Uvm, PageState::Resident, K::EvictForced).is_some());
        assert!(step(ProtocolFamily::GpuVm, PageState::Resident, K::EvictForced).is_none());
        // Double evict is illegal everywhere.
        for fam in [ProtocolFamily::GpuVm, ProtocolFamily::Uvm] {
            assert!(step(fam, PageState::Unmapped, K::EvictClean).is_none());
        }
    }

    #[test]
    fn payload_table_enforced() {
        use TraceEventKind as K;
        assert!(payload_error(K::Fault, 0, 1).is_none());
        assert!(payload_error(K::Fault, 0, 2).is_some());
        assert!(payload_error(K::Fill, 0, 0).is_some());
        assert!(payload_error(K::Fill, 0, 4096).is_none());
        assert!(payload_error(K::EvictClean, 0, 4096).is_some());
        assert!(payload_error(K::EvictDirty, 0, 0).is_some());
        assert!(payload_error(K::EvictForced, 0, 0).is_none());
        assert!(payload_error(K::EvictForced, 0, 4096).is_none());
        // wr-complete `page` is the completion-queue id: any value.
        assert!(payload_error(K::WrComplete, 3, 4).is_none());
        assert!(payload_error(K::WrComplete, 0, 5).is_some());
        assert!(payload_error(K::WrComplete, 0, 4).is_none());
    }
}
