//! Bounded schedule-perturbation determinism certifier (DPOR-lite).
//!
//! The repo's determinism story so far is anecdotal: golden traces and
//! `Metrics::fingerprint()` equality are asserted for *the* recorded
//! schedule. This module certifies the stronger property the paper's
//! protocol implies: for commuting fault pairs — pairs the
//! happens-before analysis proves independent — the order of arrival
//! must not change any deterministic counter. That is the partial-order
//! reduction insight (DPOR) scaled down to a bounded certifier:
//! instead of exploring every interleaving, re-drive replay under a
//! budgeted set of transposed schedules and assert
//! [`Metrics::fingerprint`] invariance against the baseline replay.
//!
//! ## Independence relation (deliberately conservative)
//!
//! Two *adjacent* recorded demand faults commute when every condition
//! holds:
//!
//! - the recorded stream contains **no evictions** (under memory
//!   pressure, fault order picks victims — orders are observable);
//! - the replay configuration's prefetcher is **stateless** for the
//!   replayed family (GPUVM: `none`; UVM: `none`/`fixed` — stride,
//!   density and history learn from fault order);
//! - the faults touch **different pages**, and under UVM different
//!   prefetch *groups* (region-relative — a group never spans
//!   regions);
//! - the stream is not truncated (a cut tail hides dependencies).
//!
//! Anything outside that scope is reported honestly as
//! [`CertOutcome::OutOfScope`] — never silently "certified". The CLI
//! (`gpuvm analyze certify`) runs the default policies, which sit
//! squarely inside the scope.
//!
//! [`Metrics::fingerprint`]: crate::metrics::Metrics::fingerprint

use super::lint::family_for;
use super::protocol::ProtocolFamily;
use crate::config::SystemConfig;
use crate::prefetch::PrefetchPolicy;
use crate::trace::{capture_run, Trace, TraceEventKind, TraceWorkload};
use anyhow::Result;

/// Default number of transposed schedules replayed per certificate.
pub const DEFAULT_BUDGET: usize = 24;

/// How a certification attempt ended.
#[derive(Debug, Clone)]
pub enum CertOutcome {
    /// Every replayed perturbation reproduced the baseline fingerprint.
    Certified,
    /// The trace/config pair is outside the conservative independence
    /// scope; nothing was (dis)proved.
    OutOfScope { reason: String },
    /// A perturbed schedule changed a deterministic counter.
    Violated {
        /// Which schedule diverged (human-readable description).
        schedule: String,
        /// Differing fingerprint entries: (name, baseline, perturbed).
        diffs: Vec<(&'static str, u64, u64)>,
    },
}

/// Outcome of certifying one trace under one replay configuration.
#[derive(Debug, Clone)]
pub struct CertifyReport {
    pub backend: String,
    pub workload: String,
    /// Recorded demand faults in the replayed stream.
    pub faults: usize,
    /// Adjacent fault pairs the independence relation admits.
    pub candidate_pairs: usize,
    /// Perturbed schedules actually replayed (≤ budget + 1 compound).
    pub schedules_run: usize,
    pub outcome: CertOutcome,
}

impl CertifyReport {
    /// Did a perturbation break fingerprint invariance?
    pub fn violated(&self) -> bool {
        matches!(self.outcome, CertOutcome::Violated { .. })
    }

    /// Was invariance positively certified (not merely out of scope)?
    pub fn certified(&self) -> bool {
        matches!(self.outcome, CertOutcome::Certified)
    }

    /// Render the certificate for terminal / CI-artifact output.
    pub fn render(&self) -> String {
        let mut s = format!(
            "determinism certificate: backend={} workload={}\n  \
             recorded faults: {}  independent adjacent pairs: {}  schedules replayed: {}\n",
            self.backend, self.workload, self.faults, self.candidate_pairs, self.schedules_run,
        );
        match &self.outcome {
            CertOutcome::Certified => s.push_str(
                "  verdict: CERTIFIED (Metrics::fingerprint invariant under every replayed \
                 perturbation)\n",
            ),
            CertOutcome::OutOfScope { reason } => {
                s.push_str(&format!("  verdict: OUT OF SCOPE ({reason})\n"));
            }
            CertOutcome::Violated { schedule, diffs } => {
                s.push_str(&format!("  verdict: VIOLATED by {schedule}\n"));
                for (name, base, got) in diffs {
                    s.push_str(&format!("    {name}: baseline {base} vs perturbed {got}\n"));
                }
            }
        }
        s
    }
}

/// Replay `trace` under `order` (or the recorded order) and return the
/// deterministic fingerprint plus whether the replay evicted anything.
fn replay_fingerprint(
    trace: &Trace,
    cfg: &SystemConfig,
    backend: &str,
    order: Option<&[usize]>,
) -> Result<(Vec<(&'static str, u64)>, bool)> {
    let mut w = match order {
        Some(o) => TraceWorkload::with_schedule(trace, o)?,
        None => TraceWorkload::new(trace),
    };
    let (events, truncated, r) = capture_run(cfg, backend, &mut w)?;
    anyhow::ensure!(
        !truncated,
        "replay capture truncated at {} events; raise trace.max_events",
        events.len()
    );
    let evicted = events.iter().any(|e| {
        matches!(
            e.kind,
            TraceEventKind::EvictClean | TraceEventKind::EvictDirty | TraceEventKind::EvictForced
        )
    });
    Ok((r.metrics.fingerprint(), evicted))
}

fn out_of_scope(trace: &Trace, backend: &str, faults: usize, reason: String) -> CertifyReport {
    CertifyReport {
        backend: backend.to_string(),
        workload: trace.meta.workload.clone(),
        faults,
        candidate_pairs: 0,
        schedules_run: 0,
        outcome: CertOutcome::OutOfScope { reason },
    }
}

/// Certify `Metrics::fingerprint` invariance of replaying `trace` under
/// (`cfg`, `backend`) against up to `budget` single adjacent
/// transpositions of independent fault pairs (plus one compound
/// schedule applying a non-overlapping subset of them all at once).
pub fn certify(
    trace: &Trace,
    cfg: &SystemConfig,
    backend: &str,
    budget: usize,
) -> Result<CertifyReport> {
    let family = family_for(backend)?;
    let w = TraceWorkload::new(trace);
    let faults: Vec<(u64, bool)> = w.fault_stream().to_vec();

    // Scope gates — each is a real dependence channel, not a shortcut.
    if trace.meta.truncated {
        return Ok(out_of_scope(
            trace,
            backend,
            faults.len(),
            "recorded stream is truncated; a cut tail hides dependencies".into(),
        ));
    }
    if trace.events.iter().any(|e| {
        matches!(
            e.kind,
            TraceEventKind::EvictClean | TraceEventKind::EvictDirty | TraceEventKind::EvictForced
        )
    }) {
        return Ok(out_of_scope(
            trace,
            backend,
            faults.len(),
            "recorded stream contains evictions; fault order picks victims under pressure".into(),
        ));
    }
    let stateless = match family {
        ProtocolFamily::GpuVm => cfg.gpuvm.prefetch_policy == PrefetchPolicy::None,
        ProtocolFamily::Uvm => matches!(
            cfg.uvm.prefetch_policy,
            PrefetchPolicy::None | PrefetchPolicy::Fixed
        ),
    };
    if !stateless {
        return Ok(out_of_scope(
            trace,
            backend,
            faults.len(),
            format!(
                "prefetcher '{:?}' learns from fault order; only stateless policies are in scope",
                match family {
                    ProtocolFamily::GpuVm => cfg.gpuvm.prefetch_policy,
                    ProtocolFamily::Uvm => cfg.uvm.prefetch_policy,
                }
            ),
        ));
    }
    if faults.len() < 2 {
        return Ok(out_of_scope(
            trace,
            backend,
            faults.len(),
            "fewer than two recorded demand faults; nothing to transpose".into(),
        ));
    }

    // Region-relative group of a fault: UVM services whole prefetch
    // groups, so two faults in one group share a DMA and do not
    // commute. GPUVM (and page-granular UVM) groups are single pages.
    let group_bytes = match family {
        ProtocolFamily::Uvm if cfg.uvm.prefetch_policy == PrefetchPolicy::Fixed => {
            cfg.uvm.prefetch_size.max(trace.meta.page_size)
        }
        _ => trace.meta.page_size,
    };
    let group_of = |page: u64| -> Option<(usize, u64)> {
        w.locate_page(page)
            .map(|(region, offset)| (region, offset / group_bytes.max(1)))
    };

    let candidates: Vec<usize> = (0..faults.len() - 1)
        .filter(|&i| {
            let (pa, pb) = (faults[i].0, faults[i + 1].0);
            pa != pb
                && match (group_of(pa), group_of(pb)) {
                    (Some(ga), Some(gb)) => ga != gb,
                    // A page outside the recorded layout is skipped by
                    // replay; do not transpose around it.
                    _ => false,
                }
        })
        .collect();
    if candidates.is_empty() {
        return Ok(out_of_scope(
            trace,
            backend,
            faults.len(),
            "no adjacent fault pair is independent under the scope relation".into(),
        ));
    }

    // Deterministic stride over the candidates — no randomness, same
    // certificate every run.
    let budget = budget.max(1);
    let stride = candidates.len().div_ceil(budget).max(1);
    let selected: Vec<usize> = candidates.iter().copied().step_by(stride).collect();

    let (baseline, evicted) = replay_fingerprint(trace, cfg, backend, None)?;
    if evicted {
        return Ok(out_of_scope(
            trace,
            backend,
            faults.len(),
            "replay evicts under this configuration; fault order picks victims".into(),
        ));
    }

    let identity: Vec<usize> = (0..faults.len()).collect();
    let diff = |perturbed: &[(&'static str, u64)]| -> Vec<(&'static str, u64, u64)> {
        baseline
            .iter()
            .zip(perturbed)
            .filter(|((_, a), (_, b))| a != b)
            .map(|(&(name, a), &(_, b))| (name, a, b))
            .collect()
    };

    let mut schedules_run = 0usize;
    for &i in &selected {
        let mut order = identity.clone();
        order.swap(i, i + 1);
        let (fp, _) = replay_fingerprint(trace, cfg, backend, Some(&order))?;
        schedules_run += 1;
        let diffs = diff(&fp);
        if !diffs.is_empty() {
            return Ok(CertifyReport {
                backend: backend.to_string(),
                workload: trace.meta.workload.clone(),
                faults: faults.len(),
                candidate_pairs: candidates.len(),
                schedules_run,
                outcome: CertOutcome::Violated {
                    schedule: format!("transposing faults #{i} and #{}", i + 1),
                    diffs,
                },
            });
        }
    }

    // One compound schedule: every selected swap that does not overlap
    // its predecessor, applied at once — catches order dependencies a
    // single transposition cannot.
    let mut order = identity.clone();
    let mut applied = 0usize;
    let mut last: Option<usize> = None;
    for &i in &selected {
        if last.is_none_or(|l| i > l + 1) {
            order.swap(i, i + 1);
            last = Some(i);
            applied += 1;
        }
    }
    if applied > 1 {
        let (fp, _) = replay_fingerprint(trace, cfg, backend, Some(&order))?;
        schedules_run += 1;
        let diffs = diff(&fp);
        if !diffs.is_empty() {
            return Ok(CertifyReport {
                backend: backend.to_string(),
                workload: trace.meta.workload.clone(),
                faults: faults.len(),
                candidate_pairs: candidates.len(),
                schedules_run,
                outcome: CertOutcome::Violated {
                    schedule: format!("compound schedule of {applied} disjoint transpositions"),
                    diffs,
                },
            });
        }
    }

    Ok(CertifyReport {
        backend: backend.to_string(),
        workload: trace.meta.workload.clone(),
        faults: faults.len(),
        candidate_pairs: candidates.len(),
        schedules_run,
        outcome: CertOutcome::Certified,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{BuildOpts, WorkloadSpec};
    use crate::trace::capture;

    fn small_cfg() -> SystemConfig {
        let mut c = SystemConfig::default();
        c.gpu.sms = 2;
        c.gpu.warps_per_sm = 2;
        // Plenty of GPU memory: the eviction-free scope.
        c.gpu.mem_bytes = 64 << 20;
        c
    }

    fn capture_small(cfg: &SystemConfig, backend: &str) -> Trace {
        let spec = WorkloadSpec::parse("va@64k").unwrap();
        let opts = BuildOpts::for_cfg(cfg);
        capture(cfg, &spec, &opts, backend).unwrap().0
    }

    #[test]
    fn default_policies_certify() {
        let cfg = small_cfg();
        for backend in ["gpuvm", "uvm"] {
            let t = capture_small(&cfg, backend);
            let r = certify(&t, &cfg, backend, 4).unwrap();
            assert!(r.certified(), "{backend}: {}", r.render());
            assert!(r.schedules_run >= 1, "{backend} replayed no schedules");
        }
    }

    #[test]
    fn stateful_prefetch_is_out_of_scope() {
        let mut cfg = small_cfg();
        cfg.gpuvm.prefetch_policy = PrefetchPolicy::Stride;
        let t = capture_small(&small_cfg(), "gpuvm");
        let r = certify(&t, &cfg, "gpuvm", 4).unwrap();
        assert!(
            matches!(r.outcome, CertOutcome::OutOfScope { .. }),
            "{}",
            r.render()
        );
        assert!(!r.violated());
    }

    #[test]
    fn eviction_heavy_trace_is_out_of_scope() {
        // The golden scenario oversubscribes GPU memory → evictions.
        let t = crate::trace::golden_capture("gpuvm").unwrap();
        let r = certify(&t, &crate::trace::golden_config(), "gpuvm", 4).unwrap();
        assert!(
            matches!(r.outcome, CertOutcome::OutOfScope { .. }),
            "{}",
            r.render()
        );
    }

    #[test]
    fn uvm_same_group_pairs_are_not_candidates() {
        // With 64 KB fixed groups, consecutive recorded group-head
        // faults are distinct groups — but the relation must hold up
        // under a page-granular check too: certify under `none`
        // prefetch, where every distinct page is its own group.
        let mut cfg = small_cfg();
        cfg.uvm.prefetch_policy = PrefetchPolicy::None;
        let t = capture_small(&cfg, "uvm");
        let r = certify(&t, &cfg, "uvm", 4).unwrap();
        assert!(r.certified(), "{}", r.render());
    }
}
