//! Page-lifecycle protocol analysis: declarative state machine,
//! trace linter, and small-scope model checker.
//!
//! Three cooperating layers, all driven from `gpuvm analyze`:
//!
//! - [`protocol`] — the page lifecycle as *data*: a declarative
//!   transition table ([`protocol::RULES`]) over
//!   [`protocol::PageState`]s, keyed by the nine
//!   [`crate::trace::TraceEventKind`]s and masked per protocol family
//!   (GPUVM's warp-driven paging vs UVM's host-driven VABlock model).
//!   The payload-validity table ([`protocol::payload_error`]) mirrors
//!   the per-kind `page`/`aux` semantics documented in
//!   [`crate::trace`]'s event table — the two are kept in sync by the
//!   conformance tests in `rust/tests/analyze.rs`.
//! - [`lint`] — replays any captured [`crate::trace::Trace`] through
//!   the state machine and reports the **first** violating event with
//!   the offending page's lifecycle history
//!   ([`lint::Violation::history`]) plus end-of-stream checks
//!   (unfilled faults, unmatched work requests). Exit-code contract:
//!   `gpuvm analyze` exits 0 on a clean trace, 1 on a violation, 2 on
//!   usage/IO errors.
//! - [`explore`] — exhaustively explores page-fault interleavings at
//!   small scope against every registered
//!   [`crate::residency::ResidencyPolicyKind`]'s victim protocol,
//!   certifying deadlock-freedom (or locating a deadlock cycle with a
//!   minimal repro schedule — `fifo-strict`'s head-wait deadlock is the
//!   canonical certified finding, see `residency/fifo.rs`).
//!
//! The linter checks *recorded* executions (one path, real
//! configuration); the model checker checks *all* executions (every
//! path, tiny configuration). Together they bound the protocol from
//! both sides.

pub mod explore;
pub mod lint;
pub mod protocol;

pub use explore::{certify_all, check_policy, CheckResult, Scope, Verdict, MODEL_SEED};
pub use lint::{lint, lint_trace, LintReport, Violation};
pub use protocol::{PageState, ProtocolFamily, ViolationKind};
