//! Page-lifecycle protocol analysis: declarative state machine, trace
//! linter, small-scope model checker, happens-before race checker, and
//! schedule-perturbation determinism certifier.
//!
//! Five cooperating layers, all driven from `gpuvm analyze`:
//!
//! - [`protocol`] — the page lifecycle as *data*: a declarative
//!   transition table ([`protocol::RULES`]) over
//!   [`protocol::PageState`]s, keyed by the nine
//!   [`crate::trace::TraceEventKind`]s and masked per protocol family
//!   (GPUVM's warp-driven paging vs UVM's host-driven VABlock model).
//!   The payload-validity table ([`protocol::payload_error`]) mirrors
//!   the per-kind `page`/`aux` semantics documented in
//!   [`crate::trace`]'s event table — the two are kept in sync by the
//!   conformance tests in `rust/tests/analyze.rs`.
//! - [`lint`] — replays any captured [`crate::trace::Trace`] through
//!   the state machine and reports the **first** violating event with
//!   the offending page's lifecycle history
//!   ([`lint::Violation::history`]) plus end-of-stream checks
//!   (unfilled faults, unmatched work requests). Exit-code contract:
//!   `gpuvm analyze` exits 0 on a clean trace, 1 on a violation, 2 on
//!   usage/IO errors.
//! - [`explore`] — exhaustively explores page-fault interleavings at
//!   small scope against every registered
//!   [`crate::residency::ResidencyPolicyKind`]'s victim protocol,
//!   certifying deadlock-freedom (or locating a deadlock cycle with a
//!   minimal repro schedule — `fifo-strict`'s head-wait deadlock is the
//!   canonical certified finding, see `residency/fifo.rs`).
//! - [`hb`] / [`race`] — the cross-actor side the per-page machine
//!   cannot see: [`hb`] derives the happens-before partial order from
//!   the stream (vector-clock lanes per NIC completion queue and per
//!   GPU evictor, causal edges per the module's edge table) and
//!   [`race`] reports what breaks it — unordered same-page conflict
//!   pairs, lost wakeups (a waiter released before its data), per-queue
//!   completion reordering, and causality violations (HB-ordered events
//!   with decreasing sim timestamps, cross-checked against the span
//!   builder so [`crate::obs::stage_split`]'s clamps are provably
//!   no-ops). `gpuvm analyze races <trace|golden|run>`.
//! - [`perturb`] — bounded schedule-perturbation determinism
//!   certification (DPOR-lite): re-drives replay under transposed
//!   schedules of HB-independent fault pairs and asserts
//!   [`crate::metrics::Metrics::fingerprint`] invariance, promoting
//!   "deterministic" from test anecdote to certified property. `gpuvm
//!   analyze certify`.
//!
//! The linter and race checker inspect *recorded* executions (one path,
//! real configuration); the model checker and certifier quantify over
//! *many* executions (every path at tiny scope; bounded reorderings of
//! the recorded path). Together they bound the protocol from both
//! sides.

pub mod explore;
pub mod hb;
pub mod lint;
pub mod perturb;
pub mod protocol;
pub mod race;

pub use explore::{certify_all, check_policy, CheckResult, Scope, Verdict, MODEL_SEED};
pub use hb::{Actor, HbEdge, HbEdgeKind, HbGraph};
pub use lint::{lint, lint_trace, LintReport, Violation};
pub use perturb::{certify, CertOutcome, CertifyReport, DEFAULT_BUDGET};
pub use protocol::{PageState, ProtocolFamily, ViolationKind};
pub use race::{check as race_check, check_trace as race_check_trace, RaceKind, RaceReport};
