//! Race & causality checker over the happens-before relation.
//!
//! Where the linter ([`crate::analyze::lint`]) asks "is each page's
//! lifecycle a legal word?", this layer asks the cross-actor questions
//! GPUVM's no-CPU-mediation claim rests on, using the HB graph
//! ([`crate::analyze::hb`]):
//!
//! - **`unordered-conflict`** — two conflicting operations on one
//!   `(gpu, page)` (fill/refill, touch, evict) with no happens-before
//!   path between them. Candidate pairs come from a lifecycle phase
//!   scan (a fill while the page is already resident, an eviction of a
//!   non-resident page, a demand fault of a resident page); each is
//!   confirmed genuinely concurrent via [`HbGraph::ordered`] before it
//!   is reported.
//! - **`lost-wakeup`** — a waiter released with no HB path from its
//!   data: a `fill` (or `spec-fill`) whose matched fetch WR had been
//!   posted but **not** completed at the moment the fill was recorded.
//! - **`completion-reorder`** — `wr_id`s on one completion queue must
//!   be observed in strictly increasing order (WRs are numbered at post
//!   time and each CQ is FIFO); any decrease means the transport or the
//!   poller reordered completions.
//! - **`causality-violation`** — every timestamped HB edge must carry
//!   non-decreasing simulated `at` ([`HbEdgeKind::timestamped`]); and,
//!   cross-checked against the span builder
//!   ([`crate::obs::span::build_spans`]), every reconstructed fault
//!   span must satisfy `start ≤ posted ≤ completed ≤ end` (joined spans
//!   exempt `posted ≥ start` — a demand join legally adopts an earlier
//!   post). Together these make [`crate::obs::stage_split`]'s clamps
//!   provably no-ops: span stages can never go negative by
//!   construction on a certified trace.
//!
//! The verbs `gpuvm analyze races <FILE|golden|run …>` drive this and
//! exit nonzero on any finding, mirroring the linter's contract.

use super::hb::{HbEdgeKind, HbGraph};
use super::lint::family_for;
use super::protocol::ProtocolFamily;
use crate::obs::span::build_spans;
use crate::trace::{Trace, TraceEventKind};
use crate::util::fxhash::FxHashMap;
use anyhow::Result;

/// Findings kept in full; anything beyond is counted as suppressed so a
/// garbage stream cannot balloon the report.
const MAX_FINDINGS: usize = 64;

/// Stable race/causality failure classes (the HB-level counterpart of
/// [`crate::analyze::protocol::ViolationKind`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RaceKind {
    /// Conflicting same-page pair with no HB path either way.
    UnorderedConflict,
    /// Waiter released before its fill's data dependency resolved.
    LostWakeup,
    /// Completion-queue `wr_id`s observed out of order.
    CompletionReorder,
    /// HB-ordered events with decreasing simulated timestamps (or a
    /// span whose stage boundaries would need clamping).
    CausalityViolation,
}

impl RaceKind {
    /// Stable report name.
    pub fn name(self) -> &'static str {
        match self {
            Self::UnorderedConflict => "unordered-conflict",
            Self::LostWakeup => "lost-wakeup",
            Self::CompletionReorder => "completion-reorder",
            Self::CausalityViolation => "causality-violation",
        }
    }
}

/// One race/causality finding.
#[derive(Debug, Clone)]
pub struct RaceFinding {
    pub kind: RaceKind,
    /// Stream index of the earlier implicated event, where recoverable.
    pub a: Option<usize>,
    /// Stream index of the later implicated event, where recoverable
    /// (span-level findings carry times in `detail` instead).
    pub b: Option<usize>,
    /// Human-readable diagnosis.
    pub detail: String,
}

/// Outcome of race-checking one trace.
#[derive(Debug)]
pub struct RaceReport {
    pub family: ProtocolFamily,
    pub backend: String,
    pub workload: String,
    /// Stream length.
    pub events_checked: usize,
    /// Vector-clock lanes (queues in use + evictors).
    pub lanes: usize,
    /// Happens-before edges derived.
    pub edges: usize,
    /// Reconstructed fault spans cross-checked against `stage_split`.
    pub spans_checked: usize,
    pub truncated: bool,
    pub findings: Vec<RaceFinding>,
    /// Findings beyond [`MAX_FINDINGS`] counted but not kept.
    pub suppressed: usize,
}

impl RaceReport {
    /// Race-free and causality-clean?
    pub fn clean(&self) -> bool {
        self.findings.is_empty() && self.suppressed == 0
    }

    /// Render the report for terminal / CI-artifact output.
    pub fn render(&self) -> String {
        let mut s = format!(
            "happens-before race check: backend={} (family {}) workload={}\n  \
             events: {}  lanes: {}  hb edges: {}  spans: {}{}\n",
            self.backend,
            self.family.name(),
            self.workload,
            self.events_checked,
            self.lanes,
            self.edges,
            self.spans_checked,
            if self.truncated {
                "  [truncated stream]"
            } else {
                ""
            }
        );
        if self.clean() {
            s.push_str("  verdict: CLEAN (race-free, causality-certified)\n");
        } else {
            let total = self.findings.len() + self.suppressed;
            s.push_str(&format!(
                "  verdict: VIOLATION [{total} finding{}]\n",
                if total == 1 { "" } else { "s" }
            ));
            for f in &self.findings {
                let at = match (f.a, f.b) {
                    (Some(a), Some(b)) => format!("#{a} ~ #{b}"),
                    (None, Some(b)) => format!("#{b}"),
                    _ => "span".to_string(),
                };
                s.push_str(&format!("  [{}] {at}: {}\n", f.kind.name(), f.detail));
            }
            if self.suppressed > 0 {
                s.push_str(&format!("  (+{} more suppressed)\n", self.suppressed));
            }
        }
        s
    }
}

/// Race-check `trace`, resolving the family from its recorded backend.
pub fn check_trace(trace: &Trace) -> Result<RaceReport> {
    Ok(check(trace, family_for(&trace.meta.backend)?))
}

/// Per-(gpu, page) lifecycle phase, mirrored from the protocol rules so
/// conflict candidates line up with what the linter would call illegal.
#[derive(Default)]
struct Phase {
    resident: bool,
    last_fill: Option<usize>,
    last_evict: Option<usize>,
    last_event: Option<usize>,
}

/// Build the HB graph and run all four checks over one stream.
pub fn check(trace: &Trace, family: ProtocolFamily) -> RaceReport {
    let _hp = crate::obs::hostprof::scope("analyze/race");
    let events = &trace.events;
    let g = HbGraph::build(events);
    let mut findings: Vec<RaceFinding> = Vec::new();
    let mut suppressed = 0usize;
    let mut push = |f: RaceFinding, findings: &mut Vec<RaceFinding>, suppressed: &mut usize| {
        if findings.len() < MAX_FINDINGS {
            findings.push(f);
        } else {
            *suppressed += 1;
        }
    };

    // 1. Edge causality: HB-ordered events must not travel back in
    // simulated time (evict-* edges exempt, see hb module docs).
    for e in &g.edges {
        if e.kind.timestamped() && events[e.from].at > events[e.to].at {
            push(
                RaceFinding {
                    kind: RaceKind::CausalityViolation,
                    a: Some(e.from),
                    b: Some(e.to),
                    detail: format!(
                        "'{}' edge travels back in time: {} at {}ns happens-before {} at {}ns",
                        e.kind.name(),
                        events[e.from].describe(),
                        events[e.from].at,
                        events[e.to].describe(),
                        events[e.to].at,
                    ),
                },
                &mut findings,
                &mut suppressed,
            );
        }
    }

    // 2–4. One forward scan: completion order per queue, lost wakeups,
    // and unordered same-page conflict candidates.
    let mut queue_last: FxHashMap<(u8, u64), (usize, u64)> = FxHashMap::default();
    let mut phases: FxHashMap<(u8, u64), Phase> = FxHashMap::default();
    for (i, e) in events.iter().enumerate() {
        match e.kind {
            TraceEventKind::WrComplete => {
                let wr_id = e.aux >> 1;
                let key = (e.gpu, e.page);
                if let Some(&(prev_i, prev_id)) = queue_last.get(&key) {
                    if wr_id <= prev_id {
                        push(
                            RaceFinding {
                                kind: RaceKind::CompletionReorder,
                                a: Some(prev_i),
                                b: Some(i),
                                detail: format!(
                                    "queue({},{}) completed wr_id {wr_id} after wr_id {prev_id}: \
                                     WRs are numbered at post time and each CQ is FIFO, so \
                                     per-queue completions must be strictly increasing",
                                    e.gpu, e.page,
                                ),
                            },
                            &mut findings,
                            &mut suppressed,
                        );
                    }
                }
                queue_last.insert(key, (i, wr_id));
            }
            TraceEventKind::Fill | TraceEventKind::SpecFill => {
                if let Some(rel) = g.fill_release.get(&i) {
                    if rel.complete.is_none() {
                        push(
                            RaceFinding {
                                kind: RaceKind::LostWakeup,
                                a: Some(rel.post),
                                b: Some(i),
                                detail: format!(
                                    "{} of gpu{} page {} released its waiter before the fetch \
                                     WR posted at #{} completed: no HB path from the data to \
                                     the release",
                                    e.kind.name(),
                                    e.gpu,
                                    e.page,
                                    rel.post,
                                ),
                            },
                            &mut findings,
                            &mut suppressed,
                        );
                    }
                }
                let ph = phases.entry((e.gpu, e.page)).or_default();
                if ph.resident {
                    if let Some(a) = ph.last_fill {
                        if g.concurrent(a, i) {
                            push(
                                RaceFinding {
                                    kind: RaceKind::UnorderedConflict,
                                    a: Some(a),
                                    b: Some(i),
                                    detail: format!(
                                        "gpu{} page {} filled at #{i} while already resident \
                                         from the fill at #{a}, and no HB path orders the two \
                                         fills",
                                        e.gpu, e.page,
                                    ),
                                },
                                &mut findings,
                                &mut suppressed,
                            );
                        }
                    }
                }
                ph.resident = true;
                ph.last_fill = Some(i);
                ph.last_event = Some(i);
            }
            TraceEventKind::Fault => {
                let ph = phases.entry((e.gpu, e.page)).or_default();
                if ph.resident {
                    if let Some(a) = ph.last_fill {
                        if g.concurrent(a, i) {
                            push(
                                RaceFinding {
                                    kind: RaceKind::UnorderedConflict,
                                    a: Some(a),
                                    b: Some(i),
                                    detail: format!(
                                        "gpu{} page {} demand-faulted at #{i} while resident \
                                         from the fill at #{a}, unordered by HB (touch/fill \
                                         conflict)",
                                        e.gpu, e.page,
                                    ),
                                },
                                &mut findings,
                                &mut suppressed,
                            );
                        }
                    }
                }
                ph.last_event = Some(i);
            }
            TraceEventKind::EvictClean
            | TraceEventKind::EvictDirty
            | TraceEventKind::EvictForced => {
                let ph = phases.entry((e.gpu, e.page)).or_default();
                if !ph.resident {
                    let a = ph.last_event;
                    if a.is_none() || a.is_some_and(|a| g.concurrent(a, i)) {
                        push(
                            RaceFinding {
                                kind: RaceKind::UnorderedConflict,
                                a,
                                b: Some(i),
                                detail: format!(
                                    "gpu{} page {} evicted at #{i} while not resident: the \
                                     eviction has no HB path from a fill of the page",
                                    e.gpu, e.page,
                                ),
                            },
                            &mut findings,
                            &mut suppressed,
                        );
                    }
                }
                ph.resident = false;
                ph.last_evict = Some(i);
                ph.last_event = Some(i);
            }
            TraceEventKind::Promote | TraceEventKind::WrPost => {
                if e.kind == TraceEventKind::Promote {
                    phases.entry((e.gpu, e.page)).or_default().last_event = Some(i);
                }
            }
        }
    }

    // 5. Span cross-check: the reconstructed fault spans must already
    // satisfy the ordering stage_split's clamps defend against.
    let spans = build_spans(events, family, trace.meta.truncated);
    for s in &spans.spans {
        let mut bad: Option<String> = None;
        if s.end < s.start {
            bad = Some(format!("fill at {}ns precedes fault at {}ns", s.end, s.start));
        } else if let Some(p) = s.posted {
            if p < s.start && !s.joined {
                bad = Some(format!(
                    "WR posted at {}ns before the fault at {}ns (non-joined span)",
                    p, s.start
                ));
            } else if s.completed.is_some_and(|c| c < p) {
                bad = Some(format!(
                    "WR completed at {}ns before its post at {p}ns",
                    s.completed.unwrap_or(0),
                ));
            }
        }
        if bad.is_none() && s.completed.is_some_and(|c| c > s.end) {
            bad = Some(format!(
                "WR completed at {}ns after the fill at {}ns",
                s.completed.unwrap_or(0),
                s.end
            ));
        }
        if let Some(why) = bad {
            push(
                RaceFinding {
                    kind: RaceKind::CausalityViolation,
                    a: None,
                    b: None,
                    detail: format!(
                        "fault span gpu{} page {}: {why} — stage_split would clamp a \
                         negative stage",
                        s.gpu, s.page,
                    ),
                },
                &mut findings,
                &mut suppressed,
            );
        }
    }

    RaceReport {
        family,
        backend: trace.meta.backend.clone(),
        workload: trace.meta.workload.clone(),
        events_checked: events.len(),
        lanes: g.lanes.len(),
        edges: g.edges.len(),
        spans_checked: spans.spans.len(),
        truncated: trace.meta.truncated,
        findings,
        suppressed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{RegionMeta, TraceEvent, TraceMeta};

    fn ev(at: u64, kind: TraceEventKind, page: u64, aux: u64) -> TraceEvent {
        TraceEvent {
            at,
            page,
            aux,
            kind,
            gpu: 0,
        }
    }

    fn mk(backend: &str, events: Vec<TraceEvent>) -> Trace {
        Trace {
            meta: TraceMeta {
                backend: backend.into(),
                workload: "synthetic".into(),
                page_size: 4096,
                seed: 0,
                truncated: false,
                regions: vec![RegionMeta {
                    len_bytes: 1 << 20,
                    read_mostly: false,
                }],
            },
            events,
        }
    }

    fn kinds(r: &RaceReport) -> Vec<RaceKind> {
        r.findings.iter().map(|f| f.kind).collect()
    }

    #[test]
    fn clean_lifecycle_certifies() {
        use TraceEventKind as K;
        let t = mk(
            "gpuvm",
            vec![
                ev(0, K::Fault, 3, 1),
                ev(10, K::WrPost, 3, 7 << 1),
                ev(20, K::WrComplete, 2, 7 << 1),
                ev(20, K::Fill, 3, 4096),
                ev(40, K::EvictDirty, 3, 4096),
            ],
        );
        let r = check(&t, ProtocolFamily::GpuVm);
        assert!(r.clean(), "{}", r.render());
        assert_eq!(r.lanes, 2); // queue(0,2) + evictor(0)
        assert_eq!(r.spans_checked, 1);
    }

    #[test]
    fn completion_reorder_detected() {
        use TraceEventKind as K;
        // Queue 1 observes wr 9 then wr 8: numbered at post time, FIFO
        // queues can never do that.
        let t = mk(
            "gpuvm",
            vec![
                ev(0, K::WrPost, 1, 8 << 1),
                ev(0, K::WrPost, 2, 9 << 1),
                ev(5, K::WrComplete, 1, 9 << 1),
                ev(6, K::WrComplete, 1, 8 << 1),
            ],
        );
        let r = check(&t, ProtocolFamily::GpuVm);
        assert_eq!(kinds(&r), vec![RaceKind::CompletionReorder]);
        assert_eq!((r.findings[0].a, r.findings[0].b), (Some(2), Some(3)));
    }

    #[test]
    fn lost_wakeup_detected() {
        use TraceEventKind as K;
        // Fill recorded before the fetch WR's completion.
        let t = mk(
            "gpuvm",
            vec![
                ev(0, K::Fault, 3, 0),
                ev(1, K::WrPost, 3, 4 << 1),
                ev(2, K::Fill, 3, 4096),
                ev(3, K::WrComplete, 0, 4 << 1),
            ],
        );
        let r = check(&t, ProtocolFamily::GpuVm);
        assert!(kinds(&r).contains(&RaceKind::LostWakeup), "{}", r.render());
    }

    #[test]
    fn unordered_double_fill_detected() {
        use TraceEventKind as K;
        let t = mk(
            "uvm",
            vec![ev(0, K::Fill, 5, 4096), ev(1, K::Fill, 5, 4096)],
        );
        let r = check(&t, ProtocolFamily::Uvm);
        assert_eq!(kinds(&r), vec![RaceKind::UnorderedConflict]);
    }

    #[test]
    fn evict_without_fill_detected() {
        use TraceEventKind as K;
        let t = mk("gpuvm", vec![ev(0, K::EvictClean, 5, 0)]);
        let r = check(&t, ProtocolFamily::GpuVm);
        assert_eq!(kinds(&r), vec![RaceKind::UnorderedConflict]);
        assert_eq!(r.findings[0].a, None);
    }

    #[test]
    fn causality_violation_on_backward_edge() {
        use TraceEventKind as K;
        // Completion stamped before its post: wr-match edge goes back
        // in time.
        let t = mk(
            "gpuvm",
            vec![
                ev(10, K::WrPost, 1, 4 << 1),
                ev(5, K::WrComplete, 0, 4 << 1),
            ],
        );
        let r = check(&t, ProtocolFamily::GpuVm);
        assert!(
            kinds(&r).contains(&RaceKind::CausalityViolation),
            "{}",
            r.render()
        );
    }

    #[test]
    fn evict_refault_timestamps_are_exempt() {
        use TraceEventKind as K;
        // GPUVM future-stamps evictions; the victim's refault may carry
        // an earlier `at` and must NOT be a causality finding.
        let t = mk(
            "gpuvm",
            vec![
                ev(0, K::Fault, 5, 0),
                ev(5, K::Fill, 5, 4096),
                ev(50, K::EvictClean, 5, 0), // stamped ahead
                ev(45, K::Fault, 5, 0),      // racing refault, earlier at
                ev(60, K::Fill, 5, 4096),
            ],
        );
        let r = check(&t, ProtocolFamily::GpuVm);
        assert!(r.clean(), "{}", r.render());
    }

    #[test]
    fn render_mentions_verdict_and_kind() {
        use TraceEventKind as K;
        let t = mk("gpuvm", vec![ev(0, K::EvictClean, 5, 0)]);
        let r = check(&t, ProtocolFamily::GpuVm);
        let out = r.render();
        assert!(out.contains("VIOLATION"));
        assert!(out.contains("unordered-conflict"));
        let clean = check(&mk("gpuvm", vec![]), ProtocolFamily::GpuVm);
        assert!(clean.render().contains("CLEAN"));
    }
}
