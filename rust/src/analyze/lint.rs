//! Trace linter: replay a captured event stream through the declarative
//! page state machine and report the first violating event.
//!
//! One [`PageState`] machine per `(gpu, page)` plus a work-request
//! ledger keyed by `wr_id` (decoded from the `wr-post`/`wr-complete`
//! aux payloads per the [`crate::trace`] table). The report carries the
//! violating event, the per-page lifecycle history leading up to it,
//! and a stable [`ViolationKind`] so tests and CI can gate on the exact
//! failure class. Truncated traces (recorder hit `trace.max_events`)
//! skip the end-of-stream completeness checks — a cut stream legally
//! ends mid-fill.

use super::protocol::{self, PageState, ProtocolFamily, ViolationKind};
use crate::coordinator::backend;
use crate::metrics::Metrics;
use crate::trace::{Trace, TraceEvent, TraceEventKind};
use crate::util::fxhash::FxHashMap;
use anyhow::Result;
use std::collections::hash_map::Entry;

/// Lifecycle-history events kept per page for violation reports.
const HISTORY: usize = 8;

/// One protocol violation: the first illegal event in the stream.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Stable failure class.
    pub kind: ViolationKind,
    /// Logical timestamp (stream index) of the violating event, or the
    /// stream length for end-of-stream violations.
    pub index: usize,
    /// The violating event (`None` for end-of-stream violations, where
    /// the problem is an event that never arrived).
    pub event: Option<TraceEvent>,
    /// Human-readable diagnosis.
    pub detail: String,
    /// The last [`HISTORY`] events touching the violating page (or WR),
    /// oldest first, each with its logical timestamp.
    pub history: Vec<(usize, TraceEvent)>,
}

/// Outcome of linting one trace.
#[derive(Debug)]
pub struct LintReport {
    pub family: ProtocolFamily,
    pub backend: String,
    pub workload: String,
    /// Events checked before stopping (the whole stream when clean).
    pub events_checked: usize,
    /// Distinct `(gpu, page)` machines driven.
    pub pages_tracked: usize,
    /// Distinct work requests observed.
    pub wrs_tracked: usize,
    pub truncated: bool,
    pub violation: Option<Violation>,
}

impl LintReport {
    /// Did the trace satisfy the protocol?
    pub fn clean(&self) -> bool {
        self.violation.is_none()
    }

    /// Render the report for terminal / CI-artifact output.
    pub fn render(&self) -> String {
        let mut s = format!(
            "protocol lint: backend={} (family {}) workload={}\n  events checked: {}  pages: {}  work requests: {}{}\n",
            self.backend,
            self.family.name(),
            self.workload,
            self.events_checked,
            self.pages_tracked,
            self.wrs_tracked,
            if self.truncated {
                "  [truncated stream: end-of-stream checks skipped]"
            } else {
                ""
            }
        );
        match &self.violation {
            None => s.push_str("  verdict: CLEAN\n"),
            Some(v) => {
                s.push_str(&format!("  verdict: VIOLATION [{}]\n", v.kind.name()));
                match &v.event {
                    Some(e) => s.push_str(&format!("  event #{}: {}\n", v.index, e.describe())),
                    None => s.push_str(&format!("  at end of stream (after event #{})\n", v.index)),
                }
                s.push_str(&format!("  detail: {}\n", v.detail));
                if !v.history.is_empty() {
                    s.push_str("  lifecycle history (oldest first):\n");
                    for (i, e) in &v.history {
                        s.push_str(&format!("    #{i} {}\n", e.describe()));
                    }
                }
            }
        }
        s
    }
}

/// Resolve the protocol family a backend's traces must satisfy, via
/// [`backend::Backend::protocol`]. Errors for backends that record no
/// lintable stream (the bulk-transfer baselines).
pub fn family_for(backend_name: &str) -> Result<ProtocolFamily> {
    let b = backend::lookup(backend_name)?;
    b.protocol().ok_or_else(|| {
        anyhow::anyhow!(
            "backend '{backend_name}' records no page-lifecycle stream to lint \
             (paged backends: gpuvm, uvm, uvm-memadvise, ideal)"
        )
    })
}

/// Lint `trace`, resolving the family from its recorded backend name.
pub fn lint_trace(trace: &Trace) -> Result<LintReport> {
    Ok(lint(trace, family_for(&trace.meta.backend)?))
}

struct PageTrack {
    state: PageState,
    history: Vec<(usize, TraceEvent)>,
}

struct WrTrack {
    posted_at: usize,
    post_event: TraceEvent,
    completed_at: Option<usize>,
}

/// Drive the state machine over the stream; stop at the first violation.
pub fn lint(trace: &Trace, family: ProtocolFamily) -> LintReport {
    let _hp = crate::obs::hostprof::scope("analyze/lint");
    let mut pages: FxHashMap<(u8, u64), PageTrack> = FxHashMap::default();
    let mut wrs: FxHashMap<u64, WrTrack> = FxHashMap::default();
    let mut violation = None;
    let mut checked = trace.events.len();

    for (i, e) in trace.events.iter().enumerate() {
        let v = check_event(family, &mut pages, &mut wrs, i, e);
        if let Some(v) = v {
            violation = Some(v);
            checked = i + 1;
            break;
        }
    }

    // End-of-stream completeness: every parked fault filled, every
    // posted WR completed. Meaningless on a truncated stream.
    if violation.is_none() && !trace.meta.truncated {
        violation = end_of_stream_check(&pages, &wrs, trace.events.len());
    }

    LintReport {
        family,
        backend: trace.meta.backend.clone(),
        workload: trace.meta.workload.clone(),
        events_checked: checked,
        pages_tracked: pages.len(),
        wrs_tracked: wrs.len(),
        truncated: trace.meta.truncated,
        violation,
    }
}

fn check_event(
    family: ProtocolFamily,
    pages: &mut FxHashMap<(u8, u64), PageTrack>,
    wrs: &mut FxHashMap<u64, WrTrack>,
    i: usize,
    e: &TraceEvent,
) -> Option<Violation> {
    match e.kind {
        TraceEventKind::WrPost => {
            let wr_id = e.aux >> 1;
            match wrs.entry(wr_id) {
                Entry::Occupied(prev) => {
                    let prev = prev.get();
                    Some(Violation {
                        kind: ViolationKind::DuplicateWrPost,
                        index: i,
                        event: Some(*e),
                        detail: format!(
                            "wr_id {wr_id} already posted at event #{}",
                            prev.posted_at
                        ),
                        history: vec![(prev.posted_at, prev.post_event)],
                    })
                }
                Entry::Vacant(slot) => {
                    slot.insert(WrTrack {
                        posted_at: i,
                        post_event: *e,
                        completed_at: None,
                    });
                    None
                }
            }
        }
        TraceEventKind::WrComplete => {
            if let Some(p) = protocol::payload_error(e.kind, e.page, e.aux) {
                return Some(Violation {
                    kind: ViolationKind::BadPayload,
                    index: i,
                    event: Some(*e),
                    detail: p,
                    history: Vec::new(),
                });
            }
            let wr_id = e.aux >> 1;
            match wrs.get_mut(&wr_id) {
                None => Some(Violation {
                    kind: ViolationKind::OrphanWrComplete,
                    index: i,
                    event: Some(*e),
                    detail: format!("completion for wr_id {wr_id}, which was never posted"),
                    history: Vec::new(),
                }),
                Some(w) => match w.completed_at {
                    Some(prev) => Some(Violation {
                        kind: ViolationKind::NegativeRefcount,
                        index: i,
                        event: Some(*e),
                        detail: format!(
                            "duplicate completion for wr_id {wr_id} (first at event #{prev}): \
                             the outstanding-WR count would go negative"
                        ),
                        history: vec![(w.posted_at, w.post_event)],
                    }),
                    None => {
                        w.completed_at = Some(i);
                        None
                    }
                },
            }
        }
        kind => {
            let track = pages.entry((e.gpu, e.page)).or_insert(PageTrack {
                state: PageState::Unmapped,
                history: Vec::new(),
            });
            let result = match protocol::step(family, track.state, kind) {
                Some(rule) => match protocol::payload_error(kind, e.page, e.aux) {
                    Some(p) => Some(Violation {
                        kind: ViolationKind::BadPayload,
                        index: i,
                        event: Some(*e),
                        detail: p,
                        history: track.history.clone(),
                    }),
                    None => {
                        track.state = rule.to;
                        None
                    }
                },
                None => {
                    let vkind = if protocol::is_evict(kind) && !track.state.is_resident() {
                        ViolationKind::EvictNonResident
                    } else {
                        ViolationKind::IllegalTransition
                    };
                    Some(Violation {
                        kind: vkind,
                        index: i,
                        event: Some(*e),
                        detail: format!(
                            "'{}' is illegal for gpu{} page {} in state '{}' under the {} profile",
                            kind.name(),
                            e.gpu,
                            e.page,
                            track.state.name(),
                            family.name()
                        ),
                        history: track.history.clone(),
                    })
                }
            };
            track.history.push((i, *e));
            if track.history.len() > HISTORY {
                track.history.remove(0);
            }
            result
        }
    }
}

fn end_of_stream_check(
    pages: &FxHashMap<(u8, u64), PageTrack>,
    wrs: &FxHashMap<u64, WrTrack>,
    stream_len: usize,
) -> Option<Violation> {
    // Earliest-parked first, for a deterministic report.
    let mut pending: Option<(usize, &PageTrack, (u8, u64))> = None;
    for (key, t) in pages {
        if t.state.is_pending_fill() {
            let parked_at = t.history.last().map_or(0, |(i, _)| *i);
            let better = match pending {
                None => true,
                Some((best, _, _)) => parked_at < best,
            };
            if better {
                pending = Some((parked_at, t, *key));
            }
        }
    }
    if let Some((parked_at, t, (gpu, page))) = pending {
        return Some(Violation {
            kind: ViolationKind::UnfilledFault,
            index: stream_len,
            event: None,
            detail: format!(
                "gpu{gpu} page {page} still '{}' at end of stream \
                 (demand fault at event #{parked_at} was never filled)",
                t.state.name()
            ),
            history: t.history.clone(),
        });
    }
    let mut open: Option<&WrTrack> = None;
    for w in wrs.values() {
        if w.completed_at.is_none() {
            let better = match open {
                None => true,
                Some(best) => w.posted_at < best.posted_at,
            };
            if better {
                open = Some(w);
            }
        }
    }
    open.map(|w| Violation {
        kind: ViolationKind::UnmatchedWrPost,
        index: stream_len,
        event: None,
        detail: format!(
            "wr_id {} posted at event #{} never completed",
            w.post_event.aux >> 1,
            w.posted_at
        ),
        history: vec![(w.posted_at, w.post_event)],
    })
}

/// Cross-check a trace's event counts against the aggregate metrics of
/// the run that produced it ([`Metrics::trace_expectations`]). Returns
/// one line per mismatch; empty means consistent. Truncated traces
/// cannot be cross-checked (the recorder dropped events).
pub fn metrics_mismatches(trace: &Trace, m: &Metrics) -> Vec<String> {
    if trace.meta.truncated {
        return vec!["stream truncated: count cross-check skipped".into()];
    }
    let mut out = Vec::new();
    for (kind_name, expect) in m.trace_expectations() {
        let kind = TraceEventKind::ALL.iter().find(|k| k.name() == kind_name).copied();
        let Some(kind) = kind else { continue };
        let got = trace.count_kind(kind) as u64;
        if got != expect {
            out.push(format!("metrics say {expect} '{kind_name}' events, trace has {got}"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{RegionMeta, TraceMeta};

    fn ev(kind: TraceEventKind, page: u64, aux: u64) -> TraceEvent {
        TraceEvent {
            at: 0,
            page,
            aux,
            kind,
            gpu: 0,
        }
    }

    fn mk(backend: &str, events: Vec<TraceEvent>) -> Trace {
        Trace {
            meta: TraceMeta {
                backend: backend.into(),
                workload: "synthetic".into(),
                page_size: 4096,
                seed: 0,
                truncated: false,
                regions: vec![RegionMeta {
                    len_bytes: 1 << 20,
                    read_mostly: false,
                }],
            },
            events,
        }
    }

    #[test]
    fn clean_demand_lifecycle() {
        use TraceEventKind as K;
        let t = mk(
            "gpuvm",
            vec![
                ev(K::Fault, 3, 1),
                ev(K::WrPost, 3, (7 << 1) | 1),
                ev(K::WrComplete, 0, 7 << 1),
                ev(K::Fill, 3, 4096),
                ev(K::EvictDirty, 3, 4096),
            ],
        );
        let r = lint(&t, ProtocolFamily::GpuVm);
        assert!(r.clean(), "{}", r.render());
        assert_eq!(r.pages_tracked, 1);
        assert_eq!(r.wrs_tracked, 1);
    }

    #[test]
    fn speculative_lifecycles_per_family() {
        use TraceEventKind as K;
        // GPUVM: spec fill, later promoted, evicted clean.
        let t = mk(
            "gpuvm",
            vec![
                ev(K::SpecFill, 5, 4096),
                ev(K::Promote, 5, 0),
                ev(K::EvictClean, 5, 0),
            ],
        );
        assert!(lint(&t, ProtocolFamily::GpuVm).clean());
        // GPUVM: demand join of an in-flight spec fill — promote, then
        // fill, no fault.
        let t = mk("gpuvm", vec![ev(K::Promote, 5, 0), ev(K::Fill, 5, 4096)]);
        assert!(lint(&t, ProtocolFamily::GpuVm).clean());
        // UVM: the same join is silent — a bare fill.
        let t = mk("uvm", vec![ev(K::Fill, 5, 4096)]);
        assert!(lint(&t, ProtocolFamily::Uvm).clean());
        // ...which GPUVM must reject.
        let r = lint(&mk("gpuvm", vec![ev(K::Fill, 5, 4096)]), ProtocolFamily::GpuVm);
        assert_eq!(
            r.violation.as_ref().unwrap().kind,
            ViolationKind::IllegalTransition
        );
    }

    #[test]
    fn truncated_stream_skips_end_checks() {
        use TraceEventKind as K;
        let mut t = mk("gpuvm", vec![ev(K::Fault, 1, 0), ev(K::WrPost, 1, 2 << 1)]);
        t.meta.truncated = true;
        assert!(lint(&t, ProtocolFamily::GpuVm).clean());
        t.meta.truncated = false;
        let r = lint(&t, ProtocolFamily::GpuVm);
        assert_eq!(
            r.violation.as_ref().unwrap().kind,
            ViolationKind::UnfilledFault
        );
    }

    #[test]
    fn unmatched_wr_post_reported() {
        use TraceEventKind as K;
        let t = mk("gpuvm", vec![ev(K::WrPost, 1, 4 << 1)]);
        let r = lint(&t, ProtocolFamily::GpuVm);
        assert_eq!(
            r.violation.as_ref().unwrap().kind,
            ViolationKind::UnmatchedWrPost
        );
    }

    #[test]
    fn violation_history_is_bounded_and_ordered() {
        use TraceEventKind as K;
        let mut events = Vec::new();
        for _ in 0..6 {
            events.push(ev(K::Fault, 9, 0));
            events.push(ev(K::Fill, 9, 4096));
            events.push(ev(K::EvictClean, 9, 0));
        }
        events.push(ev(K::EvictClean, 9, 0)); // double evict
        let r = lint(&mk("gpuvm", events), ProtocolFamily::GpuVm);
        let v = r.violation.unwrap();
        assert_eq!(v.kind, ViolationKind::EvictNonResident);
        assert!(v.history.len() <= HISTORY);
        let idxs: Vec<usize> = v.history.iter().map(|(i, _)| *i).collect();
        let mut sorted = idxs.clone();
        sorted.sort_unstable();
        assert_eq!(idxs, sorted);
    }

    #[test]
    fn bad_payloads_flagged() {
        use TraceEventKind as K;
        let r = lint(
            &mk("gpuvm", vec![ev(K::Fault, 1, 0), ev(K::Fill, 1, 0)]),
            ProtocolFamily::GpuVm,
        );
        assert_eq!(
            r.violation.as_ref().unwrap().kind,
            ViolationKind::BadPayload
        );
    }
}
