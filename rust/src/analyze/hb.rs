//! Happens-before over a captured trace: clock lanes, causal edges,
//! vector clocks.
//!
//! The protocol linter ([`crate::analyze::lint`]) checks each page's
//! lifecycle as an isolated regular language; nothing there relates
//! events *across* actors. This module builds that relation: the
//! happens-before (HB) partial order the runtimes are supposed to
//! maintain between warps, NIC completion queues, and the per-GPU
//! evictor, derived purely from the recorded stream.
//!
//! ## Actor lanes
//!
//! Each sequential actor gets one vector-clock lane:
//!
//! - **`queue(gpu, q)`** — one lane per NIC completion queue.
//!   `wr-complete` events carry the queue id in `page` (see the
//!   [`crate::trace`] payload table) and are totally ordered within
//!   their lane (CQ polling is FIFO).
//! - **`evictor(gpu)`** — the per-GPU victim selector; eviction events
//!   are totally ordered within it (one circular buffer scan per GPU).
//!
//! Faults, fills, and promotes do **not** get lanes of their own: the
//! capture format does not record which warp observed a fault (leader
//! election coalesces them), so per-warp program order is not
//! recoverable from a trace. Those events still participate in HB
//! through the causal edges below — they join and propagate clocks
//! without ticking a lane component.
//!
//! ## Edge table
//!
//! | edge            | from → to                                      |
//! |-----------------|------------------------------------------------|
//! | `queue-fifo`    | consecutive `wr-complete`s on one queue        |
//! | `evictor-order` | consecutive evictions by one GPU's evictor     |
//! | `wr-match`      | `wr-post` → its `wr-complete` (same `wr_id`)   |
//! | `service-post`  | `fault` → the fetch WR posted to service it    |
//! | `data-release`  | fetch `wr-complete` → the fill it releases     |
//! | `fault-fill`    | `fault` (or in-flight `promote` join) → `fill` |
//! | `spec-promote`  | `spec-fill` → the first demand `promote`       |
//! | `fill-evict`    | a page's latest fill → its eviction            |
//! | `evict-refault` | eviction → the same page's next demand fault   |
//! | `evict-refill`  | eviction → the same page's next (re)fill       |
//!
//! Every edge points forward in *stream* order (execution order). Most
//! also imply non-decreasing simulated `at` timestamps — the causality
//! check in [`crate::analyze::race`] enforces exactly that — but the
//! two `evict-*` edges are exempt: both runtimes future-stamp an
//! eviction by the unmap/check latency, so a racing refault of the
//! victim page may legally carry an earlier `at` while still being
//! causally after the eviction in stream order
//! ([`HbEdgeKind::timestamped`]).
//!
//! Vector clocks are dense (one `u32` per lane — the lane set is small:
//! queues in use plus one evictor per GPU); [`HbGraph::ordered`] answers
//! reachability exactly by walking predecessor edges, which the race
//! checker only does for the handful of candidate findings it reports.

use crate::trace::{TraceEvent, TraceEventKind};
use crate::util::fxhash::FxHashMap;

/// One sequential actor — a vector-clock lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Actor {
    /// A NIC completion queue (GPUVM: one of the RNIC QPs; UVM: the
    /// driver's single copy queue 0).
    Queue { gpu: u8, queue: u64 },
    /// The per-GPU victim selector.
    Evictor { gpu: u8 },
}

impl Actor {
    /// Stable display label, e.g. `queue(0,3)` / `evictor(0)`.
    pub fn label(self) -> String {
        match self {
            Self::Queue { gpu, queue } => format!("queue({gpu},{queue})"),
            Self::Evictor { gpu } => format!("evictor({gpu})"),
        }
    }
}

/// Why one event happens-before another (see the module edge table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HbEdgeKind {
    QueueFifo,
    EvictorOrder,
    WrMatch,
    ServicePost,
    DataRelease,
    FaultFill,
    SpecPromote,
    FillEvict,
    EvictRefault,
    EvictRefill,
}

impl HbEdgeKind {
    /// Stable report name.
    pub fn name(self) -> &'static str {
        match self {
            Self::QueueFifo => "queue-fifo",
            Self::EvictorOrder => "evictor-order",
            Self::WrMatch => "wr-match",
            Self::ServicePost => "service-post",
            Self::DataRelease => "data-release",
            Self::FaultFill => "fault-fill",
            Self::SpecPromote => "spec-promote",
            Self::FillEvict => "fill-evict",
            Self::EvictRefault => "evict-refault",
            Self::EvictRefill => "evict-refill",
        }
    }

    /// Does this edge promise non-decreasing simulated `at` timestamps?
    /// The `evict-*` edges do not: evictions are future-stamped by the
    /// unmap/check latency, so the victim's next fault/fill may carry an
    /// earlier `at` while still being causally later in stream order.
    pub fn timestamped(self) -> bool {
        !matches!(self, Self::EvictRefault | Self::EvictRefill)
    }
}

/// One happens-before edge between stream indices (`from < to`).
#[derive(Debug, Clone, Copy)]
pub struct HbEdge {
    pub from: usize,
    pub to: usize,
    pub kind: HbEdgeKind,
}

/// How a fill's data dependency resolved at the moment the waiter was
/// released — the evidence behind the lost-wakeup check.
#[derive(Debug, Clone, Copy)]
pub struct FillRelease {
    /// Stream index of the fetch `wr-post` the fill consumed.
    pub post: usize,
    /// Stream index of that WR's completion, if it had been observed by
    /// the time the fill (waiter release) was recorded. `None` means
    /// the waiter was released before its data arrived.
    pub complete: Option<usize>,
}

/// Per-(gpu, page) scan state used while building the graph.
#[derive(Default)]
struct PageCtx {
    /// Open demand episode: a `fault` or in-flight-join `promote`.
    pending: Option<usize>,
    /// Fetch WR currently in flight for this page (`wr_id`).
    inflight: Option<u64>,
    /// Latest resident-making fill (demand or speculative).
    last_fill: Option<usize>,
    /// Unconsumed speculative fill awaiting its `promote`.
    spec_fill: Option<usize>,
    /// Latest eviction not yet followed by a refault/refill.
    last_evict: Option<usize>,
}

/// The happens-before relation of one captured stream.
pub struct HbGraph {
    /// Actor lanes, indexed by lane id (vector-clock component).
    pub lanes: Vec<Actor>,
    /// All causal edges, in discovery (stream) order.
    pub edges: Vec<HbEdge>,
    /// Per-event vector clock (`lanes.len()` components each).
    pub clocks: Vec<Vec<u32>>,
    /// Data-dependency evidence per fill / spec-fill stream index.
    pub fill_release: FxHashMap<usize, FillRelease>,
    /// Incoming-edge sources per event, for exact reachability.
    preds: Vec<Vec<usize>>,
}

impl HbGraph {
    /// Build the HB graph for a stream in one forward scan (plus a lane
    /// enumeration pass). Tolerates malformed streams — lint findings
    /// are the linter's job; this just skips edges it cannot match.
    pub fn build(events: &[TraceEvent]) -> Self {
        // Pass 1: enumerate lanes so clocks can be dense vectors.
        let mut lanes: Vec<Actor> = Vec::new();
        let mut queue_lane: FxHashMap<(u8, u64), usize> = FxHashMap::default();
        let mut evictor_lane: FxHashMap<u8, usize> = FxHashMap::default();
        for e in events {
            match e.kind {
                TraceEventKind::WrComplete => {
                    queue_lane.entry((e.gpu, e.page)).or_insert_with(|| {
                        lanes.push(Actor::Queue {
                            gpu: e.gpu,
                            queue: e.page,
                        });
                        lanes.len() - 1
                    });
                }
                TraceEventKind::EvictClean
                | TraceEventKind::EvictDirty
                | TraceEventKind::EvictForced => {
                    evictor_lane.entry(e.gpu).or_insert_with(|| {
                        lanes.push(Actor::Evictor { gpu: e.gpu });
                        lanes.len() - 1
                    });
                }
                _ => {}
            }
        }

        let dim = lanes.len();
        let mut g = Self {
            lanes,
            edges: Vec::new(),
            clocks: Vec::with_capacity(events.len()),
            fill_release: FxHashMap::default(),
            preds: vec![Vec::new(); events.len()],
        };
        let mut lane_clock: Vec<Vec<u32>> = vec![vec![0; dim]; dim];
        let mut last_on_lane: Vec<Option<usize>> = vec![None; dim];
        let mut post_of: FxHashMap<u64, usize> = FxHashMap::default();
        let mut complete_of: FxHashMap<u64, usize> = FxHashMap::default();
        let mut pages: FxHashMap<(u8, u64), PageCtx> = FxHashMap::default();

        // Pass 2: edges, then the event's clock from its predecessors.
        for (i, e) in events.iter().enumerate() {
            let mut new_edges: Vec<HbEdge> = Vec::new();
            let mut edge = |from: usize, kind: HbEdgeKind| {
                new_edges.push(HbEdge { from, to: i, kind });
            };
            let mut lane: Option<usize> = None;
            match e.kind {
                TraceEventKind::Fault => {
                    let ctx = pages.entry((e.gpu, e.page)).or_default();
                    if let Some(ev) = ctx.last_evict.take() {
                        edge(ev, HbEdgeKind::EvictRefault);
                    }
                    ctx.pending = Some(i);
                }
                TraceEventKind::WrPost => {
                    let wr_id = e.aux >> 1;
                    post_of.insert(wr_id, i);
                    if e.aux & 1 == 0 {
                        // Fetch (host → GPU): ties the page's episode to
                        // the transport.
                        let ctx = pages.entry((e.gpu, e.page)).or_default();
                        if let Some(p) = ctx.pending {
                            edge(p, HbEdgeKind::ServicePost);
                        }
                        ctx.inflight = Some(wr_id);
                    }
                }
                TraceEventKind::WrComplete => {
                    let wr_id = e.aux >> 1;
                    if let Some(&p) = post_of.get(&wr_id) {
                        edge(p, HbEdgeKind::WrMatch);
                    }
                    let l = queue_lane[&(e.gpu, e.page)];
                    if let Some(prev) = last_on_lane[l] {
                        edge(prev, HbEdgeKind::QueueFifo);
                    }
                    complete_of.insert(wr_id, i);
                    lane = Some(l);
                }
                TraceEventKind::Fill | TraceEventKind::SpecFill => {
                    let ctx = pages.entry((e.gpu, e.page)).or_default();
                    if e.kind == TraceEventKind::Fill {
                        if let Some(p) = ctx.pending.take() {
                            edge(p, HbEdgeKind::FaultFill);
                        }
                    } else {
                        ctx.spec_fill = Some(i);
                    }
                    if let Some(wr) = ctx.inflight.take() {
                        if let Some(&post) = post_of.get(&wr) {
                            g.fill_release.insert(
                                i,
                                FillRelease {
                                    post,
                                    complete: complete_of.get(&wr).copied(),
                                },
                            );
                        }
                        if let Some(&c) = complete_of.get(&wr) {
                            edge(c, HbEdgeKind::DataRelease);
                        }
                    }
                    if let Some(ev) = ctx.last_evict.take() {
                        edge(ev, HbEdgeKind::EvictRefill);
                    }
                    ctx.last_fill = Some(i);
                }
                TraceEventKind::Promote => {
                    let ctx = pages.entry((e.gpu, e.page)).or_default();
                    match ctx.spec_fill.take() {
                        // First demand touch of a resident speculative
                        // page.
                        Some(s) => edge(s, HbEdgeKind::SpecPromote),
                        // GPUVM demand join of an in-flight speculative
                        // fetch: opens an episode the fill will close.
                        None => ctx.pending = Some(i),
                    }
                }
                TraceEventKind::EvictClean
                | TraceEventKind::EvictDirty
                | TraceEventKind::EvictForced => {
                    let ctx = pages.entry((e.gpu, e.page)).or_default();
                    if let Some(f) = ctx.last_fill {
                        edge(f, HbEdgeKind::FillEvict);
                    }
                    let l = evictor_lane[&e.gpu];
                    if let Some(prev) = last_on_lane[l] {
                        edge(prev, HbEdgeKind::EvictorOrder);
                    }
                    ctx.last_evict = Some(i);
                    ctx.spec_fill = None;
                    lane = Some(l);
                }
            }

            // Clock: join predecessors (and the lane), tick own lane.
            let mut clock = vec![0u32; dim];
            for ne in &new_edges {
                for (c, p) in clock.iter_mut().zip(&g.clocks[ne.from]) {
                    *c = (*c).max(*p);
                }
                g.preds[i].push(ne.from);
            }
            if let Some(l) = lane {
                for (c, p) in clock.iter_mut().zip(&lane_clock[l]) {
                    *c = (*c).max(*p);
                }
                clock[l] += 1;
                lane_clock[l].clone_from(&clock);
                last_on_lane[l] = Some(i);
            }
            g.clocks.push(clock);
            g.edges.append(&mut new_edges);
        }
        g
    }

    /// Exact happens-before reachability: is there a causal path
    /// `a → … → b`? (Reflexive: `ordered(x, x)` is true.) Walks
    /// predecessor edges backward from `b`; edges always point forward
    /// in stream order, so the walk is bounded by `b`'s prefix.
    pub fn ordered(&self, a: usize, b: usize) -> bool {
        if a == b {
            return true;
        }
        if a > b {
            return false;
        }
        let mut visited = vec![false; b + 1];
        let mut stack = vec![b];
        while let Some(v) = stack.pop() {
            for &p in &self.preds[v] {
                if p == a {
                    return true;
                }
                if p > a && !visited[p] {
                    visited[p] = true;
                    stack.push(p);
                }
            }
        }
        false
    }

    /// Are two events concurrent (neither happens-before the other)?
    pub fn concurrent(&self, a: usize, b: usize) -> bool {
        !self.ordered(a, b) && !self.ordered(b, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: u64, kind: TraceEventKind, page: u64, aux: u64) -> TraceEvent {
        TraceEvent {
            at,
            page,
            aux,
            kind,
            gpu: 0,
        }
    }

    #[test]
    fn demand_chain_is_fully_ordered() {
        use TraceEventKind as K;
        // fault → post → complete → fill on one page.
        let events = vec![
            ev(0, K::Fault, 7, 1),
            ev(10, K::WrPost, 7, 5 << 1),
            ev(20, K::WrComplete, 2, 5 << 1),
            ev(20, K::Fill, 7, 4096),
        ];
        let g = HbGraph::build(&events);
        assert_eq!(g.lanes, vec![Actor::Queue { gpu: 0, queue: 2 }]);
        for a in 0..events.len() {
            for b in a + 1..events.len() {
                assert!(g.ordered(a, b), "#{a} should precede #{b}");
                assert!(!g.ordered(b, a));
            }
        }
        let kinds: Vec<HbEdgeKind> = g.edges.iter().map(|e| e.kind).collect();
        assert!(kinds.contains(&HbEdgeKind::ServicePost));
        assert!(kinds.contains(&HbEdgeKind::WrMatch));
        assert!(kinds.contains(&HbEdgeKind::DataRelease));
        assert!(kinds.contains(&HbEdgeKind::FaultFill));
        let rel = g.fill_release[&3];
        assert_eq!((rel.post, rel.complete), (1, Some(2)));
    }

    #[test]
    fn unrelated_pages_are_concurrent() {
        use TraceEventKind as K;
        let events = vec![
            ev(0, K::Fault, 1, 0),
            ev(0, K::Fault, 2, 0),
            ev(5, K::Fill, 1, 4096),
            ev(5, K::Fill, 2, 4096),
        ];
        let g = HbGraph::build(&events);
        assert!(g.concurrent(0, 1));
        assert!(g.concurrent(2, 3));
        assert!(g.ordered(0, 2) && g.ordered(1, 3));
        assert!(g.concurrent(0, 3) && g.concurrent(1, 2));
    }

    #[test]
    fn queue_fifo_orders_unrelated_completions() {
        use TraceEventKind as K;
        // Two WRs for different pages completing on the same queue are
        // lane-ordered; on different queues they are concurrent.
        let same = vec![
            ev(0, K::WrPost, 1, 3 << 1),
            ev(0, K::WrPost, 2, 4 << 1),
            ev(9, K::WrComplete, 0, 3 << 1),
            ev(9, K::WrComplete, 0, 4 << 1),
        ];
        let g = HbGraph::build(&same);
        assert!(g.ordered(2, 3));
        let cross = vec![
            ev(0, K::WrPost, 1, 3 << 1),
            ev(0, K::WrPost, 2, 4 << 1),
            ev(9, K::WrComplete, 0, 3 << 1),
            ev(9, K::WrComplete, 1, 4 << 1),
        ];
        let g = HbGraph::build(&cross);
        assert_eq!(g.lanes.len(), 2);
        assert!(g.concurrent(2, 3));
    }

    #[test]
    fn eviction_lifecycle_edges() {
        use TraceEventKind as K;
        let events = vec![
            ev(0, K::Fault, 5, 0),
            ev(1, K::Fill, 5, 4096),
            ev(2, K::EvictClean, 5, 0),
            ev(3, K::Fault, 5, 0),
            ev(4, K::Fill, 5, 4096),
        ];
        let g = HbGraph::build(&events);
        assert_eq!(g.lanes, vec![Actor::Evictor { gpu: 0 }]);
        // fill → evict → refault → refill: one causal chain.
        assert!(g.ordered(1, 2) && g.ordered(2, 3) && g.ordered(2, 4));
        let kinds: Vec<HbEdgeKind> = g.edges.iter().map(|e| e.kind).collect();
        assert!(kinds.contains(&HbEdgeKind::FillEvict));
        assert!(kinds.contains(&HbEdgeKind::EvictRefault));
    }

    #[test]
    fn spec_promote_edge_and_inflight_join() {
        use TraceEventKind as K;
        // Resident speculative page promoted on first demand touch.
        let g = HbGraph::build(&[
            ev(0, K::SpecFill, 9, 4096),
            ev(5, K::Promote, 9, 0),
        ]);
        assert!(matches!(g.edges[..], [HbEdge { from: 0, to: 1, kind: HbEdgeKind::SpecPromote }]));
        // GPUVM in-flight join: promote opens the episode a fill closes.
        let g = HbGraph::build(&[ev(0, K::Promote, 9, 0), ev(5, K::Fill, 9, 4096)]);
        assert!(g
            .edges
            .iter()
            .any(|e| e.kind == HbEdgeKind::FaultFill && (e.from, e.to) == (0, 1)));
    }

    #[test]
    fn lost_wakeup_evidence_recorded() {
        use TraceEventKind as K;
        // Fill released before its fetch WR completed: fill_release has
        // no completion index.
        let events = vec![
            ev(0, K::Fault, 3, 0),
            ev(1, K::WrPost, 3, 8 << 1),
            ev(2, K::Fill, 3, 4096),
            ev(3, K::WrComplete, 0, 8 << 1),
        ];
        let g = HbGraph::build(&events);
        let rel = g.fill_release[&2];
        assert_eq!((rel.post, rel.complete), (1, None));
    }
}
