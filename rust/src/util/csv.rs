//! Minimal CSV writer for bench outputs (`target/bench_results/*.csv`).

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// A CSV table accumulated in memory and flushed to disk.
pub struct CsvWriter {
    path: PathBuf,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new<P: AsRef<Path>>(path: P, header: &[&str]) -> Self {
        Self {
            path: path.as_ref().to_path_buf(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Standard location for bench outputs.
    pub fn bench_result(name: &str, header: &[&str]) -> Self {
        let dir = Path::new("target/bench_results");
        let _ = fs::create_dir_all(dir);
        Self::new(dir.join(format!("{name}.csv")), header)
    }

    pub fn row<I: IntoIterator<Item = S>, S: ToString>(&mut self, cells: I) {
        let row: Vec<String> = cells.into_iter().map(|c| c.to_string()).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "csv row width mismatch for {}",
            self.path.display()
        );
        self.rows.push(row);
    }

    pub fn flush(&self) -> std::io::Result<()> {
        if let Some(parent) = self.path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(&self.path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for row in &self.rows {
            let escaped: Vec<String> = row.iter().map(|c| escape(c)).collect();
            writeln!(f, "{}", escaped.join(","))?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

fn escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join("gpuvm_csv_test");
        let _ = fs::create_dir_all(&dir);
        let path = dir.join("t.csv");
        let mut w = CsvWriter::new(&path, &["a", "b"]);
        w.row(["1", "x,y"]);
        w.row(["2", "plain"]);
        w.flush().unwrap();
        let text = fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n2,plain\n");
    }

    #[test]
    #[should_panic(expected = "width mismatch")]
    fn width_checked() {
        let mut w = CsvWriter::new("/tmp/unused.csv", &["a", "b"]);
        w.row(["only-one"]);
    }
}
