//! Tiny argument parser (the offline build has no `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    /// program name as invoked
    pub prog: String,
    positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Self {
        let mut it = std::env::args();
        let prog = it.next().unwrap_or_else(|| "gpuvm".into());
        Self::parse(prog, it.collect())
    }

    pub fn parse(prog: String, argv: Vec<String>) -> Self {
        let mut positional = Vec::new();
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags
                        .entry(stripped.to_string())
                        .or_default()
                        .push(argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.entry(stripped.to_string()).or_default().push(String::new());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Self {
            prog,
            positional,
            flags,
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => parse_u64_with_suffix(v)
                .ok_or_else(|| anyhow::anyhow!("--{key}: cannot parse '{v}' as integer")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        Ok(self.get_u64(key, default as u64)? as usize)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse '{v}' as float")),
        }
    }
}

/// Parse integers with the size suffixes used throughout the configs:
/// `4k`/`4K` = 4096, `2m`/`2M` = 2 MiB, `1g`/`1G` = 1 GiB (binary units).
pub fn parse_u64_with_suffix(s: &str) -> Option<u64> {
    let s = s.trim();
    let (num, mult) = match s.chars().last()? {
        'k' | 'K' => (&s[..s.len() - 1], 1024u64),
        'm' | 'M' => (&s[..s.len() - 1], 1024 * 1024),
        'g' | 'G' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    let base: f64 = num.parse().ok()?;
    if base < 0.0 {
        return None;
    }
    Some((base * mult as f64).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Args {
        Args::parse("gpuvm".into(), argv.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn positional_and_flags() {
        // NB: a bare boolean flag greedily consumes a following non-flag
        // token, so `--verbose` must come last or use `--verbose=`.
        let a = parse(&["run", "extra", "--app", "bfs", "--pages=8k", "--verbose"]);
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.get("app"), Some("bfs"));
        assert_eq!(a.get_u64("pages", 0).unwrap(), 8192);
        assert!(a.has("verbose"));
        assert_eq!(a.positional(), &["run".to_string(), "extra".to_string()]);
    }

    #[test]
    fn last_flag_wins_and_all_collected() {
        let a = parse(&["--x", "1", "--x", "2"]);
        assert_eq!(a.get("x"), Some("2"));
        assert_eq!(a.get_all("x"), vec!["1", "2"]);
    }

    #[test]
    fn suffixes() {
        assert_eq!(parse_u64_with_suffix("4k"), Some(4096));
        assert_eq!(parse_u64_with_suffix("2M"), Some(2 * 1024 * 1024));
        assert_eq!(parse_u64_with_suffix("1g"), Some(1 << 30));
        assert_eq!(parse_u64_with_suffix("1.5k"), Some(1536));
        assert_eq!(parse_u64_with_suffix("17"), Some(17));
        assert_eq!(parse_u64_with_suffix("bogus"), None);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_u64("missing", 7).unwrap(), 7);
        assert_eq!(a.get_or("m", "d"), "d");
        assert_eq!(a.get_f64("f", 1.5).unwrap(), 1.5);
    }
}
