//! Minimal wallclock bench harness (the offline build has no `criterion`).
//!
//! Benches in this repo mostly report *simulated* time from the DES, but the
//! §Perf pass also needs wallclock measurements of the simulator itself;
//! this module provides warmup + repeated timing with mean/std and a
//! stable text report format shared by all `rust/benches/*.rs` binaries.

use super::stats::Online;
use std::time::Instant;

/// Result of timing one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

/// Time `f` with `warmup` throwaway runs then `iters` measured runs.
pub fn time<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut o = Online::new();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        o.push(t0.elapsed().as_secs_f64());
    }
    Timing {
        name: name.to_string(),
        iters: iters.max(1),
        mean_s: o.mean(),
        std_s: o.std(),
        min_s: o.min(),
    }
}

impl Timing {
    pub fn report(&self) -> String {
        format!(
            "{:<48} {:>10.3} ms ±{:>7.3} ms  (min {:.3} ms, n={})",
            self.name,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.min_s * 1e3,
            self.iters
        )
    }
}

/// Pretty banner used by the figure benches so output sections are greppable.
pub fn banner(title: &str) {
    let line = "=".repeat(title.len().max(8) + 8);
    println!("\n{line}\n=== {title} ===\n{line}");
}

/// Format a simulated duration (ns) human-readably.
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const KB: u64 = 1024;
    const MB: u64 = 1024 * KB;
    const GB: u64 = 1024 * MB;
    if b >= GB {
        format!("{:.2} GiB", b as f64 / GB as f64)
    } else if b >= MB {
        format!("{:.2} MiB", b as f64 / MB as f64)
    } else if b >= KB {
        format!("{:.2} KiB", b as f64 / KB as f64)
    } else {
        format!("{b} B")
    }
}

/// Format a bandwidth (bytes/sec) as GB/s (decimal, matching the paper).
pub fn fmt_gbps(bytes_per_sec: f64) -> String {
    format!("{:.2} GB/s", bytes_per_sec / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_runs() {
        let mut count = 0u32;
        let t = time("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(t.iters, 5);
        assert!(t.mean_s >= 0.0);
        assert!(!t.report().is_empty());
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500), "500 ns");
        assert_eq!(fmt_ns(1_500), "1.500 µs");
        assert_eq!(fmt_ns(2_000_000), "2.000 ms");
        assert_eq!(fmt_ns(3_000_000_000), "3.000 s");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(fmt_gbps(6.5e9), "6.50 GB/s");
    }
}
