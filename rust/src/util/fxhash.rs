//! FxHash — the in-tree replacement for the `rustc-hash` crate (the
//! offline build environment carries no registry). Same algorithm the
//! Rust compiler uses: a fast multiply-rotate hash, perfectly adequate
//! for the simulator's small integer-keyed maps and deterministic across
//! runs (no per-process random state, unlike `std`'s SipHash).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// `HashMap` keyed by [`FxHasher`].
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// `HashSet` keyed by [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_u128(&mut self, i: u128) {
        self.add_to_hash(i as u64);
        self.add_to_hash((i >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_and_set_work() {
        let mut m: FxHashMap<(usize, u64), u32> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert((i as usize % 7, i), i as u32);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m.get(&(3, 10)), Some(&10));
        let mut s: FxHashSet<u64> = FxHashSet::default();
        s.insert(42);
        s.insert(42);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn deterministic() {
        let hash = |x: u64| {
            let mut h = FxHasher::default();
            h.write_u64(x);
            h.finish()
        };
        assert_eq!(hash(12345), hash(12345));
        assert_ne!(hash(1), hash(2));
    }

    #[test]
    fn with_capacity_constructor() {
        let m: FxHashMap<u64, u64> = FxHashMap::with_capacity_and_hasher(64, Default::default());
        assert!(m.capacity() >= 64);
    }
}
