//! In-tree utilities replacing crates unavailable in the offline build:
//! PRNG (`rand`), property testing (`proptest`), bench harness
//! (`criterion`), CSV output, CLI parsing (`clap`), and small stats.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod fxhash;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
