//! Mini property-testing harness (the offline build has no `proptest`).
//!
//! A property is a closure over a seeded [`crate::util::rng::Rng`]; the
//! harness runs it across many derived seeds and, on failure, re-runs with
//! the failing seed reported so the case can be pinned as a regression test.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla_extension rpath)
//! use gpuvm::util::proptest::check;
//! check("addition commutes", 256, |rng| {
//!     let a = rng.gen_range(1000) as i64;
//!     let b = rng.gen_range(1000) as i64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Base seed; override with `GPUVM_PROPTEST_SEED` to replay a failure.
fn base_seed() -> u64 {
    std::env::var("GPUVM_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Number-of-cases multiplier; `GPUVM_PROPTEST_CASES_MULT` scales all
/// `check` call sites (useful for a longer soak).
fn cases_mult() -> f64 {
    std::env::var("GPUVM_PROPTEST_CASES_MULT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Run `prop` for `cases` derived seeds. Panics (with the failing seed in
/// the message) if any case fails.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u32, mut prop: F) {
    let cases = ((cases as f64 * cases_mult()).ceil() as u32).max(1);
    let mut seeder = Rng::new(base_seed() ^ fxhash(name));
    for case in 0..cases {
        let seed = seeder.next_u64();
        let mut rng = Rng::new(seed);
        let result = catch_unwind(AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (replay: seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

/// FNV-1a over the property name so distinct properties use distinct
/// seed streams even with the same base seed.
fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum symmetric", 64, |rng| {
            let a = rng.gen_range(100);
            let b = rng.gen_range(100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            check("always fails", 4, |_| panic!("boom"));
        }));
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{err:?}"));
        assert!(msg.contains("replay: seed"), "{msg}");
    }

    #[test]
    fn distinct_names_distinct_streams() {
        let mut first_a = 0;
        check("name-a", 1, |rng| first_a = rng.next_u64());
        let mut first_b = 0;
        check("name-b", 1, |rng| first_b = rng.next_u64());
        assert_ne!(first_a, first_b);
    }
}
