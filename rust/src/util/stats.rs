//! Small statistics helpers: online mean/variance, percentile estimation
//! over recorded samples, and fixed-bucket latency histograms.

/// Online mean / variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Online {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Fold another accumulator in (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Online) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Log2-bucketed histogram for latencies in nanoseconds. Bucket `i` covers
/// `[2^i, 2^(i+1))` ns; bucket 0 covers `[0, 2)`.
#[derive(Clone, Debug)]
pub struct LatencyHist {
    buckets: [u64; 64],
    online: Online,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        Self {
            buckets: [0; 64],
            online: Online::new(),
        }
    }

    pub fn record(&mut self, ns: u64) {
        let idx = if ns < 2 { 0 } else { 63 - ns.leading_zeros() as usize };
        self.buckets[idx.min(63)] += 1;
        self.online.push(ns as f64);
    }

    pub fn count(&self) -> u64 {
        self.online.count()
    }
    pub fn mean_ns(&self) -> f64 {
        self.online.mean()
    }
    pub fn max_ns(&self) -> f64 {
        self.online.max()
    }

    /// Fold another histogram in (bucket-wise; summary stats via
    /// [`Online::merge`]).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.online.merge(&other.online);
    }

    /// Approximate percentile from the log buckets (upper bucket bound).
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1).min(63);
            }
        }
        u64::MAX
    }
}

/// Geometric mean of a slice of positive ratios (used for speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_mean_var() {
        let mut o = Online::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            o.push(x);
        }
        assert!((o.mean() - 5.0).abs() < 1e-12);
        assert!((o.var() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(o.min(), 2.0);
        assert_eq!(o.max(), 9.0);
    }

    #[test]
    fn hist_percentiles_monotone() {
        let mut h = LatencyHist::new();
        for i in 1..=1000u64 {
            h.record(i * 100);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.percentile(50.0) <= h.percentile(99.0));
        assert!(h.mean_ns() > 0.0);
    }

    #[test]
    fn geomean_of_twos() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_structs_are_safe() {
        let o = Online::new();
        assert_eq!(o.mean(), 0.0);
        let h = LatencyHist::new();
        assert_eq!(h.percentile(99.0), 0);
    }
}
