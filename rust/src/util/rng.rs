//! Deterministic PRNGs for the simulator and test harnesses.
//!
//! The offline build environment has no `rand` crate, so we implement the
//! two standard small generators the simulator needs: SplitMix64 (seeding,
//! stream splitting) and xoshiro256** (bulk generation). Both are
//! well-studied public-domain algorithms; determinism across runs is a hard
//! requirement for the discrete-event simulation (same seed ⇒ same event
//! order ⇒ same simulated timings).

/// SplitMix64: tiny, fast, passes BigCrush; used to seed xoshiro and to
/// derive independent streams from a base seed.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the simulator's workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (for per-component RNGs).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Raw generator state, for canonical decision-state signatures
    /// (`ResidencyPolicy::state_sig`): two generators with equal state
    /// words produce identical streams.
    pub fn state_words(&self) -> [u64; 4] {
        self.s
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough for
    /// simulation purposes; we accept the tiny modulo bias of the fast path
    /// only for n that are not close to 2^64).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // 128-bit multiply-shift.
        let x = self.next_u64();
        ((x as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed with mean `mean` (for arrival jitter).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-18);
        -mean * u.ln()
    }

    /// Sample from a (truncated) Zipf-like distribution over `[0, n)` with
    /// exponent `alpha`, via inverse-CDF on a precomputed harmonic
    /// approximation. Used by the power-law graph generators.
    pub fn zipf(&mut self, n: u64, alpha: f64) -> u64 {
        // Rejection-inversion (Hörmann) simplified: adequate for generator
        // shape, not for statistical studies.
        debug_assert!(n >= 1);
        if n == 1 {
            return 0;
        }
        let u = self.f64();
        // Inverse of CDF of continuous pareto truncated at [1, n+1).
        let one_minus = 1.0 - alpha;
        let h = |x: f64| -> f64 { x.powf(one_minus) };
        let hn = h(n as f64 + 1.0);
        let x = (h(1.0) + u * (hn - h(1.0))).powf(1.0 / one_minus);
        (x as u64 - 1).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Random f32 vector in [-1, 1), for synthetic datasets.
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| (self.f64() * 2.0 - 1.0) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            let x = r.zipf(10, 1.2) as usize;
            counts[x] += 1;
        }
        assert!(counts[0] > counts[4], "{counts:?}");
        assert!(counts[0] > counts[9], "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut base = Rng::new(5);
        let mut s1 = base.split();
        let mut s2 = base.split();
        let same = (0..100).filter(|_| s1.next_u64() == s2.next_u64()).count();
        assert!(same < 3);
    }
}
