//! Minimal JSON string escaping (the offline build has no `serde`).
//! The single escaper behind every hand-rolled JSON emitter
//! ([`crate::coordinator::report`], [`crate::trace::format`]), so an
//! escaping fix lands everywhere at once.

/// Quote and escape `s` as a JSON string literal.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_quotes_backslashes_and_control_chars() {
        assert_eq!(json_string("plain"), "\"plain\"");
        assert_eq!(json_string("a\"b"), "\"a\\\"b\"");
        assert_eq!(json_string("a\\b"), "\"a\\\\b\"");
        assert_eq!(json_string("a\nb"), "\"a\\nb\"");
        assert_eq!(json_string("a\tb"), "\"a\\u0009b\"");
        assert_eq!(json_string("π"), "\"π\"");
    }
}
