//! Minimal JSON support (the offline build has no `serde`): the single
//! string escaper behind every hand-rolled JSON emitter
//! ([`crate::coordinator::report`], [`crate::trace::format`]) — so an
//! escaping fix lands everywhere at once — plus a small
//! recursive-descent *parser* ([`parse_json`] → [`JsonValue`]) for the
//! few places that must read JSON back: the self-perf trajectory
//! tooling ([`crate::obs::perfcmp`]) parsing `BENCH_*.json` and
//! `bench_selfperf` output.

/// Quote and escape `s` as a JSON string literal.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// A parsed JSON document. Objects keep insertion order (`Vec` of
/// pairs, not a map) so round-trip diagnostics read like the file.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    /// All JSON numbers as f64 — the self-perf schema's integers stay
    /// exact well within f64's 2^53 integer range.
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object member by key (first match), or `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse a complete JSON document. Strict: rejects trailing garbage,
/// trailing commas, unquoted keys. Errors carry a byte offset.
pub fn parse_json(input: &str) -> anyhow::Result<JsonValue> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        anyhow::bail!("trailing data at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> anyhow::Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<JsonValue> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => anyhow::bail!("unexpected input at byte {}", self.pos),
        }
    }

    fn object(&mut self) -> anyhow::Result<JsonValue> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            members.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(members));
                }
                _ => anyhow::bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string at byte {}", self.pos),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("short \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            // No surrogate-pair support: the emitters
                            // here only \u-escape control chars.
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?,
                            );
                            self.pos += 4;
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one full UTF-8 scalar (input is &str, so
                    // boundaries are valid).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<JsonValue> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number '{text}' at byte {start}"))?;
        Ok(JsonValue::Num(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_quotes_backslashes_and_control_chars() {
        assert_eq!(json_string("plain"), "\"plain\"");
        assert_eq!(json_string("a\"b"), "\"a\\\"b\"");
        assert_eq!(json_string("a\\b"), "\"a\\\\b\"");
        assert_eq!(json_string("a\nb"), "\"a\\nb\"");
        assert_eq!(json_string("a\tb"), "\"a\\u0009b\"");
        assert_eq!(json_string("π"), "\"π\"");
    }

    #[test]
    fn parses_nested_documents() {
        let v = parse_json(
            r#"{"a": 1, "b": [true, null, -2.5e1], "s": "x\"y", "o": {"k": "v"}}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").and_then(JsonValue::as_u64), Some(1));
        let b = v.get("b").and_then(JsonValue::as_array).unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].as_bool(), Some(true));
        assert_eq!(b[1], JsonValue::Null);
        assert_eq!(b[2].as_f64(), Some(-25.0));
        assert_eq!(v.get("s").and_then(JsonValue::as_str), Some("x\"y"));
        assert_eq!(
            v.get("o").and_then(|o| o.get("k")).and_then(JsonValue::as_str),
            Some("v")
        );
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn parser_round_trips_the_escaper() {
        for s in ["plain", "a\"b", "a\\b", "a\nb", "a\tb", "π"] {
            let doc = format!("{{\"k\": {}}}", json_string(s));
            let v = parse_json(&doc).unwrap();
            assert_eq!(v.get("k").and_then(JsonValue::as_str), Some(s), "{doc}");
        }
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "{]",
            "{\"a\":}",
            "{\"a\":1,}",
            "[1 2]",
            "{\"a\":1} x",
            "\"unterminated",
            "{'a': 1}",
            "nul",
        ] {
            assert!(parse_json(bad).is_err(), "accepted {bad:?}");
        }
        // Non-integer where a count is expected.
        let v = parse_json("1.5").unwrap();
        assert_eq!(v.as_u64(), None);
        assert_eq!(v.as_f64(), Some(1.5));
    }
}
