//! The GPUVM runtime — the paper's contribution (§3).
//!
//! GPU threads manage their own virtual memory: on a page-table miss the
//! warp's leader acquires a frame from the circular page buffer — victim
//! choice delegated to the pluggable [`crate::residency`] policy
//! (`gpuvm.residency_policy`; the default `fifo-refcount` is §3.3/§5.4's
//! reference-priority FIFO, extracted bit for bit) — builds a
//! work request, posts it to one of many parallel queues on the
//! configured [`crate::fabric::Transport`], rings the doorbell (batched,
//! §3.2), and polls the completion queue. Warps that fault on a page
//! already in flight join its waiter list instead of posting again
//! (inter-warp coalescing, Fig 6). The host OS is never on the path;
//! the engine (RDMA NIC by default — `gpuvm.transport`) moves the page
//! across the fabric.
//!
//! Functionally, backed host regions really move bytes into the frame
//! pool, so data integrity under paging + eviction is testable; timing
//! flows through the transport and PCIe models on the shared DES clock.

use crate::config::SystemConfig;
use crate::fabric::{self, Completion, Transport, WorkRequest};
use crate::mem::{FrameId, FramePool, FrameState, HostMemory, PageId};
use crate::memsys::{AccessResult, Ev, MemCtx, MemEvent, MemorySystem, PageAccess, SlotId, Wakes};
use crate::metrics::Metrics;
use crate::pcie::Dir;
use crate::prefetch::{self, FaultEvent, PrefetchPolicy, Prefetcher};
use crate::residency::{self, ResidencyPolicy, Universe, VictimChoice, VictimQuery};
use crate::sim::{us, Engine, SimTime};
use crate::trace::{self, TraceEventKind};
use crate::util::fxhash::{FxHashMap, FxHashSet};
use std::collections::VecDeque;

/// Key for a fault: which GPU wants which host page.
type FaultKey = (usize, PageId);

/// Can this frame be taken *right now*? The single definition behind
/// both `GpuVmSystem::frame_usable` and the residency policy's usable
/// oracle: no queued waiters, and Free or Resident-with-drained
/// references (never mid-fill).
fn usable_frame(pool: &FramePool, waiters: &[VecDeque<PageId>], f: FrameId) -> bool {
    let fr = pool.frame(f);
    waiters[f.0 as usize].is_empty()
        && match fr.state {
            FrameState::Free => true,
            FrameState::Resident(_) => fr.refcount == 0,
            FrameState::Filling(_) => false,
        }
}

/// A fault from first miss to data-resident.
#[derive(Debug)]
struct Inflight {
    /// Frame assigned (None while queued behind a busy frame).
    frame: Option<FrameId>,
    /// Slots to wake when the page becomes resident. A slot appears once
    /// per distinct page it waits on.
    waiters: Vec<SlotId>,
    /// Any waiter wants to write.
    write: bool,
    /// When the first miss occurred (fault-latency histogram).
    started: SimTime,
    /// When the fetch WR was posted ([`crate::obs::stage_split`]'s
    /// queue/transfer boundary). None until `post_now` runs; stays the
    /// prefetch-time post on a demand join (the split clamps it).
    posted: Option<SimTime>,
    /// Issued by the prefetcher, no demand waiter yet; such fetches
    /// don't enter the fault-latency histogram.
    speculative: bool,
}

/// Per-queue doorbell batching state (§3.2: post_number / batch_counter /
/// one leader rings per batch).
#[derive(Debug, Default, Clone)]
struct QueueBatch {
    pending: u32,
    /// Epoch guards stale BatchFlush timers.
    epoch: u64,
}

/// What to do when a synchronous write-back completes.
#[derive(Debug)]
struct FetchAfterWriteback {
    gpu: usize,
    page: PageId,
}

/// Why a WR exists (determines the completion handling).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WrPurpose {
    Fetch,
    /// Eviction write-back that gates a fetch (paper §5.3: synchronous).
    WritebackSync,
    /// Fire-and-forget write-back (async_writeback extension).
    WritebackAsync,
}

/// A work request waiting for a free queue. §3.2: a leader whose
/// post_number exceeds the current batch "must wait for the current
/// batch to finish" — so in-flight WRs are bounded by
/// num_qps × fault_batch, which is exactly the Little's-law knee of
/// Fig 11.
#[derive(Debug, Clone, Copy)]
struct PendingWr {
    gpu: usize,
    page: PageId,
    dir: Dir,
    purpose: WrPurpose,
    /// For a synchronous write-back: the page whose fetch follows.
    follow: Option<PageId>,
}

pub struct GpuVmSystem {
    cfg: SystemConfig,
    /// The page-migration engine (`gpuvm.transport`): owns the link
    /// topology and services posted WRs doorbell by doorbell.
    fabric: Box<dyn Transport>,
    /// Per-GPU frame pool; victim selection is delegated to the
    /// pluggable residency policy below.
    pools: Vec<FramePool>,
    /// Per-GPU, per-frame queue of pages waiting to take over the frame.
    frame_waiters: Vec<Vec<VecDeque<PageId>>>,
    inflight: FxHashMap<FaultKey, Inflight>,
    wr_fault: FxHashMap<u64, FaultKey>,
    wr_writeback: FxHashMap<u64, FetchAfterWriteback>,
    next_wr: u64,
    next_queue: usize,
    batches: Vec<QueueBatch>,
    /// WRs in flight (rung, not yet completed) per queue.
    queue_busy: Vec<u32>,
    /// Leaders waiting for a free queue (FIFO).
    backlog: VecDeque<PendingWr>,
    /// Reused completion buffer (hot path, §Perf).
    completion_buf: Vec<Completion>,
    /// Reused WR buffer for batched backlog posting (hot path, §Perf).
    wr_batch: Vec<WorkRequest>,
    /// Frames each slot currently references.
    holds: FxHashMap<SlotId, Vec<(usize, FrameId)>>,
    /// Outstanding pages per blocked slot; wake at 0.
    slot_pending: FxHashMap<SlotId, u32>,
    /// Pages that were resident once and got evicted, with the fill
    /// count at eviction time (refetch + reuse-distance accounting).
    evicted_at: FxHashMap<FaultKey, u64>,
    /// Per-GPU fills started so far (the reuse-distance clock; per-GPU
    /// so one GPU's traffic can't dilute another's thrash signal).
    fills: Vec<u64>,
    /// The pluggable residency policy answering victim selection
    /// (`gpuvm.residency_policy`); slots are frame indices.
    residency: Box<dyn ResidencyPolicy>,
    /// Pages per 2 MB VABlock (`uvm.evict_block`), the block hint the
    /// `tree-lru` policy clusters on.
    pages_per_block: u64,
    /// The pluggable prefetch policy observing the demand-fault stream.
    prefetcher: Box<dyn Prefetcher>,
    /// Fast gate: skip the prefetch path entirely under `none`.
    prefetch_enabled: bool,
    /// Prefetched pages (in flight or resident) not yet touched by a
    /// demand access — resolved into `prefetch_hits` on first use or
    /// `prefetch_wasted` on eviction.
    prefetched: FxHashSet<FaultKey>,
    /// Reused candidate buffer (one `on_fault` call per leader fault).
    pf_buf: Vec<u64>,
    /// Optional event-trace sink ([`crate::trace`]): records the
    /// canonical fault/fill/evict/WR stream when attached.
    sink: Option<trace::SharedSink>,
    /// Optional interval sampler ([`crate::obs`]), ticked from the
    /// access/event hot paths when attached (default None: one branch).
    obs: Option<crate::obs::SharedObs>,
    backed: bool,
}

impl GpuVmSystem {
    pub fn new(cfg: &SystemConfig) -> Self {
        Self::with_backing(cfg, false)
    }

    /// `backed = true` keeps real page bytes in the frame pools (required
    /// by the PJRT compute path and the data-integrity tests).
    pub fn with_backing(cfg: &SystemConfig, backed: bool) -> Self {
        let frames = cfg.gpu_frames();
        let pools = (0..cfg.gpu.num_gpus)
            .map(|_| FramePool::new(frames, cfg.gpuvm.page_size, backed))
            .collect();
        let frame_waiters = (0..cfg.gpu.num_gpus)
            .map(|_| vec![VecDeque::new(); frames])
            .collect();
        Self {
            fabric: fabric::build(&cfg.gpuvm.transport, cfg)
                .expect("transport name validated by SystemConfig::validate"),
            pools,
            frame_waiters,
            inflight: FxHashMap::default(),
            wr_fault: FxHashMap::default(),
            wr_writeback: FxHashMap::default(),
            next_wr: 1,
            next_queue: 0,
            batches: vec![QueueBatch::default(); cfg.gpuvm.num_qps],
            queue_busy: vec![0; cfg.gpuvm.num_qps],
            backlog: VecDeque::new(),
            completion_buf: Vec::with_capacity(64),
            wr_batch: Vec::new(),
            holds: FxHashMap::default(),
            slot_pending: FxHashMap::default(),
            evicted_at: FxHashMap::default(),
            fills: vec![0; cfg.gpu.num_gpus],
            // The seed derivation is the historical inline one, so the
            // extracted `random` engine replays the exact pre-subsystem
            // probe sequence.
            residency: residency::build(
                cfg.gpuvm.residency_policy,
                Universe::Frames {
                    frames_per_gpu: frames,
                },
                cfg.gpu.num_gpus,
                cfg.seed ^ 0x6b75_766d,
            ),
            pages_per_block: (cfg.uvm.evict_block / cfg.gpuvm.page_size).max(1),
            prefetcher: prefetch::build(
                cfg.gpuvm.prefetch_policy,
                cfg,
                cfg.gpuvm.prefetch_degree,
            ),
            prefetch_enabled: cfg.gpuvm.prefetch_policy != PrefetchPolicy::None,
            prefetched: FxHashSet::default(),
            pf_buf: Vec::new(),
            sink: None,
            obs: None,
            backed,
            cfg: cfg.clone(),
        }
    }

    /// Direct access to a GPU's frame pool (PJRT compute path, tests).
    pub fn pool(&self, gpu: usize) -> &FramePool {
        &self.pools[gpu]
    }

    pub fn pool_mut(&mut self, gpu: usize) -> &mut FramePool {
        &mut self.pools[gpu]
    }

    /// Structural invariants across all pools (property tests).
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        for p in &self.pools {
            p.check_invariants()?;
        }
        Ok(())
    }

    // ---- frame acquisition (the circular buffer of Fig 5) ----

    /// Ask the residency policy for a victim. The `usable` oracle it
    /// sees is exactly the `frame_usable` predicate (one shared
    /// definition, so the oracle and the defensive re-checks can't
    /// drift).
    fn choose_victim(&mut self, gpu: usize, demand: bool, m: &Metrics) -> VictimChoice {
        let _hp = crate::obs::hostprof::scope("gpuvm/victim");
        let pool = &self.pools[gpu];
        let waiters = &self.frame_waiters[gpu];
        let usable = move |s: u64| usable_frame(pool, waiters, FrameId(s as u32));
        self.residency.pick_victim(&VictimQuery {
            gpu,
            demand,
            prefetch_issued: m.prefetched_pages,
            prefetch_accuracy: m.prefetch_accuracy(),
            usable: &usable,
        })
    }

    /// Try to take the next frame per the residency policy. Returns the
    /// frame if usable now, or None after enqueueing `page` on a busy
    /// frame's waiter list.
    fn acquire_frame(
        &mut self,
        now: SimTime,
        gpu: usize,
        page: PageId,
        hm: &mut HostMemory,
        eng: &mut Engine<Ev>,
        m: &mut Metrics,
    ) -> Option<FrameId> {
        match self.choose_victim(gpu, true, m) {
            VictimChoice::Take(s) => {
                self.try_take_frame(now, gpu, FrameId(s as u32), page, hm, eng, m)
            }
            VictimChoice::WaitOn(s) => {
                self.enqueue_frame_wait(gpu, FrameId(s as u32), page, m);
                None
            }
            VictimChoice::GiveUp => {
                // Contract violation (demand faults must park somewhere);
                // fall back to waiting on frame 0 so liveness survives a
                // buggy policy.
                debug_assert!(false, "residency policy gave up on a demand fault");
                self.enqueue_frame_wait(gpu, FrameId(0), page, m);
                None
            }
        }
    }

    fn frame_usable(&self, gpu: usize, f: FrameId) -> bool {
        usable_frame(&self.pools[gpu], &self.frame_waiters[gpu], f)
    }

    /// Take `f` for `page` if possible now; otherwise enqueue and return
    /// None. On success the fetch (and any write-back) is initiated.
    fn try_take_frame(
        &mut self,
        now: SimTime,
        gpu: usize,
        f: FrameId,
        page: PageId,
        hm: &mut HostMemory,
        eng: &mut Engine<Ev>,
        m: &mut Metrics,
    ) -> Option<FrameId> {
        if !self.frame_usable(gpu, f) {
            self.enqueue_frame_wait(gpu, f, page, m);
            return None;
        }
        self.start_fill(now, gpu, f, page, hm, eng, m);
        Some(f)
    }

    fn enqueue_frame_wait(&mut self, gpu: usize, f: FrameId, page: PageId, m: &mut Metrics) {
        m.eviction_waits += 1;
        self.frame_waiters[gpu][f.0 as usize].push_back(page);
    }

    /// Evict `f` if it holds a page, then begin filling it with `page`
    /// and post the fetch WR (after a synchronous write-back if dirty).
    fn start_fill(
        &mut self,
        now: SimTime,
        gpu: usize,
        f: FrameId,
        page: PageId,
        hm: &mut HostMemory,
        eng: &mut Engine<Ev>,
        m: &mut Metrics,
    ) {
        let t = now + self.cfg.gpuvm.eviction_check_ns;
        let mut fetch_deferred = false;
        if let FrameState::Resident(_) = self.pools[gpu].frame(f).state {
            // Functional write-back happens immediately; the timing cost
            // is the write-back WR below.
            let bytes = self.pools[gpu].frame_bytes(f).map(|b| b.to_vec());
            let (old_page, dirty) = self.pools[gpu].evict(f).expect("evict checked usable");
            m.evictions += 1;
            if dirty {
                m.evictions_dirty += 1;
            } else {
                m.evictions_clean += 1;
            }
            self.evicted_at.insert((gpu, old_page), self.fills[gpu]);
            self.residency.on_evict(gpu, f.0 as u64);
            trace::emit(
                &self.sink,
                t,
                gpu,
                if dirty {
                    TraceEventKind::EvictDirty
                } else {
                    TraceEventKind::EvictClean
                },
                old_page.0,
                if dirty { self.cfg.gpuvm.page_size } else { 0 },
            );
            if self.prefetched.remove(&(gpu, old_page)) {
                // Prefetched, never touched, now evicted: pure waste.
                m.prefetch_wasted += 1;
            }
            if dirty {
                if let Some(b) = bytes {
                    hm.write_page(old_page, &b).expect("write-back target");
                }
                m.bytes_out += self.cfg.gpuvm.page_size;
                let purpose = if self.cfg.gpuvm.async_writeback {
                    WrPurpose::WritebackAsync
                } else {
                    // Paper §5.3: write-back is synchronous — the fetch
                    // waits for the out-transfer's completion.
                    fetch_deferred = true;
                    WrPurpose::WritebackSync
                };
                self.submit(
                    t,
                    PendingWr {
                        gpu,
                        page: old_page,
                        dir: Dir::Out,
                        purpose,
                        follow: fetch_deferred.then_some(page),
                    },
                    eng,
                    m,
                );
            }
        }
        self.pools[gpu]
            .begin_fill(page, f)
            .expect("frame free after evict");
        self.fills[gpu] += 1;
        let mut speculative = false;
        if let Some(fl) = self.inflight.get_mut(&(gpu, page)) {
            fl.frame = Some(f);
            speculative = fl.speculative;
        }
        self.residency
            .on_fill(gpu, f.0 as u64, page.0 / self.pages_per_block, speculative);
        if !fetch_deferred {
            self.submit(
                t,
                PendingWr {
                    gpu,
                    page,
                    dir: Dir::In,
                    purpose: WrPurpose::Fetch,
                    follow: None,
                },
                eng,
                m,
            );
        }
    }

    /// Take a frame for a speculative fetch of `page` *without ever
    /// waiting*: the policy sees a non-demand query (so the §5.4
    /// ablations stay meaningful with prefetch on), and where a demand
    /// fault would enqueue behind a busy frame, a prefetch is simply
    /// dropped — waiter slots belong to demand. Returns false when no
    /// frame is takeable now.
    fn acquire_frame_speculative(
        &mut self,
        now: SimTime,
        gpu: usize,
        page: PageId,
        hm: &mut HostMemory,
        eng: &mut Engine<Ev>,
        m: &mut Metrics,
    ) -> bool {
        match self.choose_victim(gpu, false, m) {
            VictimChoice::Take(s) => {
                let f = FrameId(s as u32);
                if self.frame_usable(gpu, f) {
                    self.start_fill(now, gpu, f, page, hm, eng, m);
                    true
                } else {
                    // Defensive re-check of the Take contract; a buggy
                    // policy costs a dropped prefetch, never a stall.
                    false
                }
            }
            VictimChoice::WaitOn(_) | VictimChoice::GiveUp => false,
        }
    }

    /// Ask the policy for candidates around a demand fault and post
    /// speculative fetches for them. Candidates ride the same RNIC
    /// queue pairs as demand work requests (extra WQEs in the current
    /// batch) but take no waiters and record no fault latency.
    #[allow(clippy::too_many_arguments)]
    fn issue_prefetches(
        &mut self,
        now: SimTime,
        gpu: usize,
        page: PageId,
        warp: u32,
        write: bool,
        hm: &mut HostMemory,
        eng: &mut Engine<Ev>,
        m: &mut Metrics,
    ) {
        let Some(rid) = hm.region_of_page(page) else {
            return;
        };
        let (base, region_pages) = {
            let r = hm.region(rid);
            (r.base_page, r.num_pages)
        };
        let ev = FaultEvent {
            gpu,
            region: rid,
            page_in_region: page.0 - base,
            region_pages,
            warp,
            write,
            now,
        };
        let mut buf = std::mem::take(&mut self.pf_buf);
        buf.clear();
        self.prefetcher.on_fault(&ev, &mut buf);
        for &idx in &buf {
            if idx >= region_pages {
                continue; // defensive: policies are bounds-tested
            }
            let key = (gpu, PageId(base + idx));
            if self.pools[gpu].lookup(key.1).is_some() || self.inflight.contains_key(&key) {
                continue; // already resident or in flight
            }
            self.inflight.insert(
                key,
                Inflight {
                    frame: None,
                    waiters: Vec::new(),
                    write: false,
                    started: now,
                    posted: None,
                    speculative: true,
                },
            );
            if self.acquire_frame_speculative(now, gpu, key.1, hm, eng, m) {
                m.prefetched_pages += 1;
                self.prefetched.insert(key);
            } else {
                // Pool saturated: back out and stop speculating.
                self.inflight.remove(&key);
                break;
            }
        }
        self.pf_buf = buf;
    }

    /// Submit a WR: post it on a free queue, or enqueue the leader in the
    /// backlog if every queue is occupied by an in-flight batch (§3.2:
    /// "it must wait for the current batch to finish"). This bounds
    /// in-flight WRs to num_qps × fault_batch — the Fig 11 knee.
    fn submit(&mut self, now: SimTime, pw: PendingWr, eng: &mut Engine<Ev>, m: &mut Metrics) {
        match self.find_free_queue() {
            Some(queue) => self.post_now(now, queue, pw, eng, m),
            None => self.backlog.push_back(pw),
        }
    }

    /// A queue can take a post if its current batch is still filling and
    /// it has no batch in flight.
    fn find_free_queue(&self) -> Option<usize> {
        let n = self.fabric.num_queues();
        for off in 0..n {
            let q = (self.next_queue + off) % n;
            if self.queue_busy[q] == 0 && self.batches[q].pending < self.cfg.gpuvm.fault_batch {
                return Some(q);
            }
        }
        None
    }

    /// Per-WR host-side bookkeeping shared by the single-post and the
    /// batched backlog-drain paths: assign the wr_id, wire the purpose
    /// maps, stamp the in-flight record, count the WR, and emit the
    /// trace event. Returns the wire-ready work request — the caller
    /// owns posting it into the fabric.
    fn prepare_wr(&mut self, t_posted: SimTime, pw: PendingWr, m: &mut Metrics) -> WorkRequest {
        let wr_id = self.next_wr;
        self.next_wr += 1;
        match pw.purpose {
            WrPurpose::Fetch => {
                self.wr_fault.insert(wr_id, (pw.gpu, pw.page));
            }
            WrPurpose::WritebackSync => {
                self.wr_writeback.insert(
                    wr_id,
                    FetchAfterWriteback {
                        gpu: pw.gpu,
                        page: pw.follow.expect("sync write-back carries its fetch"),
                    },
                );
            }
            WrPurpose::WritebackAsync => {}
        }
        if pw.purpose == WrPurpose::Fetch {
            if let Some(fl) = self.inflight.get_mut(&(pw.gpu, pw.page)) {
                fl.posted = Some(t_posted);
            }
        }
        m.work_requests += 1;
        trace::emit(
            &self.sink,
            t_posted,
            pw.gpu,
            TraceEventKind::WrPost,
            pw.page.0,
            (wr_id << 1) | matches!(pw.dir, Dir::Out) as u64,
        );
        WorkRequest {
            wr_id,
            page: pw.page,
            bytes: self.cfg.gpuvm.page_size,
            dir: pw.dir,
            gpu: pw.gpu,
        }
    }

    /// Batch bookkeeping after `n` WRs landed on `queue` at `t_posted`:
    /// arm the flush timer when the first WR opened a fresh batch, ring
    /// when the batch filled. Replays exactly what `n` successive
    /// single posts did — the timer is armed even when a later WR of
    /// the same burst fills the batch (the epoch guard retires the
    /// stale flush, as it always has), and only the last WR can fill
    /// the batch because callers never post past the remaining room.
    fn note_posted(
        &mut self,
        t_posted: SimTime,
        queue: usize,
        n: u32,
        eng: &mut Engine<Ev>,
        m: &mut Metrics,
    ) {
        let b = &mut self.batches[queue];
        let fresh_batch = b.pending == 0;
        b.pending += n;
        if fresh_batch && self.cfg.gpuvm.fault_batch > 1 {
            // First of a batch: arm the flush timer.
            let epoch = b.epoch;
            eng.schedule(
                t_posted + us(self.cfg.gpuvm.batch_timeout_us),
                Ev::Mem(MemEvent::BatchFlush { queue, epoch }),
            );
        }
        if self.batches[queue].pending >= self.cfg.gpuvm.fault_batch {
            self.next_queue = (queue + 1) % self.fabric.num_queues();
            self.ring(t_posted + self.cfg.gpuvm.doorbell_ns, queue, eng, m);
        }
    }

    fn post_now(
        &mut self,
        now: SimTime,
        queue: usize,
        pw: PendingWr,
        eng: &mut Engine<Ev>,
        m: &mut Metrics,
    ) {
        let t_posted = now + self.cfg.gpuvm.wr_insert_ns;
        let wr = self.prepare_wr(t_posted, pw, m);
        self.fabric.post(queue, wr).expect("free queue accepts a post");
        crate::obs::hostprof::count("gpuvm/wr_posted", 1);
        self.note_posted(t_posted, queue, 1, eng, m);
    }

    fn ring(&mut self, now: SimTime, queue: usize, eng: &mut Engine<Ev>, m: &mut Metrics) {
        let b = &mut self.batches[queue];
        if b.pending == 0 {
            return;
        }
        self.queue_busy[queue] += b.pending;
        b.pending = 0;
        b.epoch += 1;
        m.doorbells += 1;
        crate::obs::hostprof::count("gpuvm/doorbells", 1);
        self.completion_buf.clear();
        let mut buf = std::mem::take(&mut self.completion_buf);
        self.fabric
            .ring_doorbell_into(now, queue, &mut buf)
            .expect("valid queue");
        for c in &buf {
            eng.schedule(
                c.at,
                Ev::Mem(MemEvent::CqCompletion {
                    queue,
                    wr_id: c.wr_id,
                }),
            );
        }
        self.completion_buf = buf;
    }

    /// A fetch completed: install bytes, mark resident, hand out refs,
    /// wake waiters. Returns the filled frame so the caller can service
    /// pages queued behind it when nobody takes a reference (a
    /// speculative fill completing with demand faults parked on its
    /// frame must not strand them).
    fn complete_fetch(
        &mut self,
        now: SimTime,
        key: FaultKey,
        hm: &mut HostMemory,
        m: &mut Metrics,
        wakes: &mut Wakes,
    ) -> (usize, FrameId) {
        let (gpu, page) = key;
        crate::obs::hostprof::count("gpuvm/fills", 1);
        let fl = self.inflight.remove(&key).expect("inflight fetch");
        let frame = fl.frame.expect("fetch had a frame");
        let bytes = if self.backed {
            hm.read_page(page).map(|b| b.to_vec())
        } else {
            None
        };
        self.pools[gpu]
            .complete_fill(frame, bytes.as_deref())
            .expect("filling frame");
        m.bytes_in += self.cfg.gpuvm.page_size;
        trace::emit(
            &self.sink,
            now,
            gpu,
            if fl.speculative {
                TraceEventKind::SpecFill
            } else {
                TraceEventKind::Fill
            },
            page.0,
            self.cfg.gpuvm.page_size,
        );
        if !fl.speculative {
            m.fault_latency.record(now.saturating_sub(fl.started));
            // Stage decomposition of that same latency: the WrComplete
            // is observed at `now` and the page maps at `now`, so the
            // trace-derived span builder sees identical inputs and the
            // two breakdowns reconcile bit for bit.
            m.record_stages(
                crate::obs::stage_split(fl.started, fl.posted, Some(now), now),
                self.cfg.gpuvm.cq_poll_interval_ns,
            );
        }
        if fl.write {
            self.pools[gpu].mark_dirty(frame);
        }
        let resume = now + self.cfg.gpuvm.cq_poll_interval_ns;
        for slot in fl.waiters {
            // Each waiter takes a reference before it runs.
            self.pools[gpu].addref(frame);
            self.holds.entry(slot).or_default().push((gpu, frame));
            let p = self
                .slot_pending
                .get_mut(&slot)
                .expect("waiter has pending count");
            *p -= 1;
            if *p == 0 {
                self.slot_pending.remove(&slot);
                wakes.push((slot, resume));
            }
        }
        (gpu, frame)
    }

    /// Tick the interval sampler (no-op when detached). Gauges: frames
    /// currently holding data (fills started minus evictions; frames
    /// mid-fill count, matching the queue-depth gauge they drive) and
    /// in-flight WRs per transport queue.
    fn obs_tick(&self, now: SimTime, m: &mut Metrics) {
        if let Some(obs) = &self.obs {
            let mut s = obs.borrow_mut();
            if s.due(now) {
                let occupied = self.fills.iter().sum::<u64>().saturating_sub(m.evictions);
                s.tick(now, m, occupied, &self.queue_busy);
            }
        }
    }

    /// A frame's refcount hit zero: if pages queue on it, start the next.
    fn service_frame_waiters(
        &mut self,
        now: SimTime,
        gpu: usize,
        frame: FrameId,
        hm: &mut HostMemory,
        eng: &mut Engine<Ev>,
        m: &mut Metrics,
    ) {
        if !self.frame_waiters[gpu][frame.0 as usize].is_empty() {
            let fr = self.pools[gpu].frame(frame);
            let free_now = match fr.state {
                FrameState::Free => true,
                FrameState::Resident(_) => fr.refcount == 0,
                FrameState::Filling(_) => false,
            };
            if free_now {
                let page = self.frame_waiters[gpu][frame.0 as usize]
                    .pop_front()
                    .unwrap();
                self.start_fill(now, gpu, frame, page, hm, eng, m);
            }
        }
    }
}

impl MemorySystem for GpuVmSystem {
    fn name(&self) -> &'static str {
        "gpuvm"
    }

    fn prepare(&mut self, _hm: &HostMemory, _m: &mut Metrics) {}

    fn access(
        &mut self,
        ctx: &mut MemCtx<'_>,
        slot: SlotId,
        gpu: usize,
        pages: &[PageAccess],
    ) -> AccessResult {
        debug_assert!(gpu < self.pools.len());
        let _hp = crate::obs::hostprof::scope("gpuvm/access");
        let now = ctx.now;
        self.obs_tick(now, ctx.m);
        let t = now + self.cfg.gpuvm.page_table_lookup_ns;
        let mut misses = 0u32;
        for pa in pages {
            match self.pools[gpu].lookup(pa.page) {
                Some((frame, true)) => {
                    ctx.m.hits += 1;
                    if self.prefetched.remove(&(gpu, pa.page)) {
                        // First demand touch of a prefetched page.
                        ctx.m.prefetch_hits += 1;
                        trace::emit(&self.sink, now, gpu, TraceEventKind::Promote, pa.page.0, 0);
                        self.residency.on_promote(gpu, frame.0 as u64);
                    } else {
                        self.residency.on_touch(gpu, frame.0 as u64);
                    }
                    self.pools[gpu].addref(frame);
                    if pa.write {
                        self.pools[gpu].mark_dirty(frame);
                    }
                    self.holds.entry(slot).or_default().push((gpu, frame));
                }
                Some((frame, false)) => {
                    // Fault in flight (another leader owns it): coalesce.
                    ctx.m.coalesced_faults += 1;
                    let fl = self
                        .inflight
                        .get_mut(&(gpu, pa.page))
                        .expect("filling frame has inflight entry");
                    fl.waiters.push(slot);
                    fl.write |= pa.write;
                    if std::mem::replace(&mut fl.speculative, false) {
                        // First demand join of a speculative fetch:
                        // fault latency counts from this miss, not from
                        // the prefetch issue.
                        fl.started = now;
                        if self.prefetched.remove(&(gpu, pa.page)) {
                            // Demanded while still in flight: the
                            // prefetch hid most of the latency.
                            ctx.m.prefetch_hits += 1;
                        }
                        trace::emit(&self.sink, now, gpu, TraceEventKind::Promote, pa.page.0, 0);
                        self.residency.on_promote(gpu, frame.0 as u64);
                    } else {
                        self.residency.on_touch(gpu, frame.0 as u64);
                    }
                    misses += 1;
                }
                None => {
                    if let Some(fl) = self.inflight.get_mut(&(gpu, pa.page)) {
                        // Queued behind a busy frame; join it.
                        ctx.m.coalesced_faults += 1;
                        fl.waiters.push(slot);
                        fl.write |= pa.write;
                        fl.speculative = false;
                        misses += 1;
                        continue;
                    }
                    // New fault: this warp's leader takes it (Fig 4).
                    ctx.m.faults += 1;
                    crate::obs::hostprof::count("gpuvm/faults", 1);
                    trace::emit(
                        &self.sink,
                        now,
                        gpu,
                        TraceEventKind::Fault,
                        pa.page.0,
                        pa.write as u64,
                    );
                    if let Some(&at) = self.evicted_at.get(&(gpu, pa.page)) {
                        ctx.m.refetches += 1;
                        // Reuse distance in fills since the eviction; a
                        // short distance is thrash — the policy threw
                        // out the live working set.
                        let d = self.fills[gpu].saturating_sub(at);
                        ctx.m.reuse_distance.record(d);
                        if d <= residency::THRASH_WINDOW {
                            ctx.m.thrash_refetches += 1;
                        }
                    }
                    self.inflight.insert(
                        (gpu, pa.page),
                        Inflight {
                            frame: None,
                            waiters: vec![slot],
                            write: pa.write,
                            started: now,
                            posted: None,
                            speculative: false,
                        },
                    );
                    let t_leader = t + self.cfg.gpuvm.leader_election_ns;
                    self.acquire_frame(t_leader, gpu, pa.page, &mut *ctx.hm, &mut *ctx.eng, &mut *ctx.m);
                    if self.prefetch_enabled {
                        // The leader's fault is the policy's observation
                        // point; candidates ride the same QPs.
                        self.issue_prefetches(
                            t_leader,
                            gpu,
                            pa.page,
                            slot.0,
                            pa.write,
                            &mut *ctx.hm,
                            &mut *ctx.eng,
                            &mut *ctx.m,
                        );
                    }
                    misses += 1;
                }
            }
        }
        if misses == 0 {
            AccessResult::Ready {
                resume_at: t + self.cfg.gpu.hbm_hit_ns,
            }
        } else {
            self.slot_pending.insert(slot, misses);
            AccessResult::Blocked
        }
    }

    fn release(&mut self, ctx: &mut MemCtx<'_>, slot: SlotId) {
        let now = ctx.now;
        let Some(held) = self.holds.remove(&slot) else {
            return;
        };
        // note: hm is not available here; frame-waiter servicing that
        // needs host bytes defers the byte copy to fetch completion, so
        // nothing here touches host data. Write-backs capture bytes at
        // evict time inside start_fill, which needs hm — so releases that
        // trigger dirty evictions route through a zero-delay event.
        let mut freed: Vec<(usize, FrameId)> = Vec::new();
        for (gpu, frame) in held {
            self.pools[gpu].unref(frame);
            if self.pools[gpu].frame(frame).refcount == 0 {
                freed.push((gpu, frame));
            }
        }
        for (gpu, frame) in freed {
            self.residency.on_drain(gpu, frame.0 as u64);
            if !self.frame_waiters[gpu][frame.0 as usize].is_empty() {
                // Defer to a zero-delay event so the eviction (and its
                // functional write-back) runs with a fresh context.
                ctx.eng.schedule(
                    now,
                    Ev::Mem(MemEvent::FrameFree {
                        gpu,
                        frame: frame.0,
                    }),
                );
            }
        }
    }

    fn on_event(&mut self, ctx: &mut MemCtx<'_>, ev: MemEvent) {
        let _hp = crate::obs::hostprof::scope("gpuvm/on_event");
        let now = ctx.now;
        self.obs_tick(now, ctx.m);
        match ev {
            MemEvent::CqCompletion { queue, wr_id } => {
                debug_assert!(self.queue_busy[queue] > 0);
                self.queue_busy[queue] -= 1;
                // Completion records are keyed by wr_id (see the trace
                // module table); the matching WrPost carries page/dir,
                // and `page` here carries the completion-queue id so the
                // happens-before analyzer can lint per-queue ordering.
                trace::emit(
                    &self.sink,
                    now,
                    0,
                    TraceEventKind::WrComplete,
                    queue as u64,
                    wr_id << 1,
                );
                if let Some(key) = self.wr_fault.remove(&wr_id) {
                    let (gpu, frame) =
                        self.complete_fetch(now, key, &mut *ctx.hm, &mut *ctx.m, &mut *ctx.wakes);
                    if self.pools[gpu].frame(frame).refcount == 0
                        && !self.frame_waiters[gpu][frame.0 as usize].is_empty()
                    {
                        // A speculative fill completed with no demand
                        // reference while pages queue behind its frame:
                        // release() will never fire for it, so service
                        // the waiters through the usual event.
                        ctx.eng.schedule(
                            now,
                            Ev::Mem(MemEvent::FrameFree {
                                gpu,
                                frame: frame.0,
                            }),
                        );
                    }
                } else if let Some(fw) = self.wr_writeback.remove(&wr_id) {
                    // Synchronous write-back done: launch the fetch.
                    self.submit(
                        now,
                        PendingWr {
                            gpu: fw.gpu,
                            page: fw.page,
                            dir: Dir::In,
                            purpose: WrPurpose::Fetch,
                            follow: None,
                        },
                        &mut *ctx.eng,
                        &mut *ctx.m,
                    );
                }
                // Async write-backs complete silently.
                // The freed queue slot drains waiting leaders (§3.2).
                // Consecutive leaders land on the same queue until its
                // batch fills (find_free_queue scans from next_queue),
                // so post them as one fabric batch: per-WR bookkeeping
                // stays, the queue insert and profiling count amortize.
                while !self.backlog.is_empty() {
                    let Some(q) = self.find_free_queue() else { break };
                    let room = self.cfg.gpuvm.fault_batch - self.batches[q].pending;
                    let take = (room as usize).min(self.backlog.len());
                    if take <= 1 {
                        let pw = self.backlog.pop_front().unwrap();
                        self.post_now(now, q, pw, &mut *ctx.eng, &mut *ctx.m);
                        continue;
                    }
                    let t_posted = now + self.cfg.gpuvm.wr_insert_ns;
                    let mut wrs = std::mem::take(&mut self.wr_batch);
                    wrs.clear();
                    for _ in 0..take {
                        let pw = self.backlog.pop_front().unwrap();
                        let wr = self.prepare_wr(t_posted, pw, &mut *ctx.m);
                        wrs.push(wr);
                    }
                    let posted = self.fabric.post_batch(q, &wrs).expect("valid queue");
                    assert_eq!(posted, take, "free queue accepts its remaining room");
                    crate::obs::hostprof::count("gpuvm/wr_posted", take as u64);
                    self.wr_batch = wrs;
                    self.note_posted(t_posted, q, take as u32, &mut *ctx.eng, &mut *ctx.m);
                }
            }
            MemEvent::FrameFree { gpu, frame } => {
                self.service_frame_waiters(
                    now,
                    gpu,
                    FrameId(frame),
                    &mut *ctx.hm,
                    &mut *ctx.eng,
                    &mut *ctx.m,
                );
            }
            MemEvent::BatchFlush { queue, epoch } => {
                if self.batches[queue].epoch == epoch && self.batches[queue].pending > 0 {
                    self.ring(
                        now + self.cfg.gpuvm.doorbell_ns,
                        queue,
                        &mut *ctx.eng,
                        &mut *ctx.m,
                    );
                }
            }
            _ => unreachable!("UVM event routed to GPUVM"),
        }
    }

    fn drain(&mut self, ctx: &mut MemCtx<'_>) -> bool {
        let now = ctx.now;
        let mut any = false;
        for q in 0..self.batches.len() {
            if self.batches[q].pending > 0 {
                self.ring(
                    now + self.cfg.gpuvm.doorbell_ns,
                    q,
                    &mut *ctx.eng,
                    &mut *ctx.m,
                );
                any = true;
            }
        }
        any
    }

    fn set_trace_sink(&mut self, sink: trace::SharedSink) {
        self.sink = Some(sink);
    }

    fn set_obs(&mut self, obs: crate::obs::SharedObs) {
        self.obs = Some(obs);
    }

    fn finalize(&mut self, m: &mut Metrics) {
        self.fabric.export_utilization(m);
        let stats = self.fabric.stats();
        // Legacy counter names, kept for the property tests and ablation
        // benches that predate the named TransportStats.
        m.bump("nic_wrs", stats.wrs_serviced);
        m.bump("nic_doorbells", stats.doorbells);
        m.bump("nic_bytes", stats.bytes_moved);
        m.transport.merge(&stats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::exec::run;
    use crate::gpu::kernel::{Access, Launch, WarpOp, Workload};
    use crate::mem::RegionId;

    /// Sequential streaming reader at one page per op.
    struct Stream {
        warps: usize,
        reads_per_warp: usize,
        region: Option<RegionId>,
        launched: bool,
        state: Vec<usize>,
    }

    impl Stream {
        fn new(warps: usize, reads: usize) -> Self {
            Self {
                warps,
                reads_per_warp: reads,
                region: None,
                launched: false,
                state: vec![0; warps],
            }
        }
    }

    impl Workload for Stream {
        fn name(&self) -> &str {
            "gpuvm-stream"
        }
        fn setup(&mut self, hm: &mut HostMemory) {
            let bytes = (self.warps * self.reads_per_warp) as u64 * 4096;
            self.region = Some(hm.register("d", bytes));
        }
        fn next_kernel(&mut self) -> Option<Launch> {
            if self.launched {
                return None;
            }
            self.launched = true;
            Some(Launch {
                warps: self.warps,
                tag: 0,
            })
        }
        fn next_op(&mut self, warp: usize) -> WarpOp {
            let s = self.state[warp];
            if s >= self.reads_per_warp {
                return WarpOp::Done;
            }
            self.state[warp] += 1;
            let idx = (warp * self.reads_per_warp + s) as u64;
            WarpOp::Access(vec![Access::Seq {
                region: self.region.unwrap(),
                start: idx * 4096,
                len: 4096,
                write: false,
            }])
        }
    }

    fn cfg(policy: PrefetchPolicy) -> SystemConfig {
        let mut c = SystemConfig::default();
        c.gpu.sms = 2;
        c.gpu.warps_per_sm = 1;
        c.gpuvm.page_size = 4096;
        c.gpu.mem_bytes = 8 << 20;
        c.gpuvm.num_qps = 16;
        c.gpuvm.prefetch_policy = policy;
        c
    }

    fn stream_run(policy: PrefetchPolicy) -> Metrics {
        let c = cfg(policy);
        let mut w = Stream::new(2, 64);
        let mut mem = GpuVmSystem::new(&c);
        run(&c, &mut w, &mut mem).unwrap().metrics
    }

    #[test]
    fn no_policy_means_every_page_faults() {
        let m = stream_run(PrefetchPolicy::None);
        assert_eq!(m.faults, 128);
        assert_eq!(m.prefetched_pages, 0);
        assert_eq!(m.prefetch_hits, 0);
        assert_eq!(m.bytes_in, 128 * 4096);
    }

    #[test]
    fn stride_policy_hides_faults_on_streaming() {
        let m = stream_run(PrefetchPolicy::Stride);
        assert!(m.prefetched_pages > 0, "stride must speculate");
        assert!(m.prefetch_hits > 0, "sequential stream uses its prefetches");
        assert!(
            m.faults < 128,
            "prefetch must absorb leader faults: {} of 128 pages",
            m.faults
        );
        // Every transfer is either a demand fetch or a counted prefetch.
        assert_eq!(m.bytes_in, (m.faults + m.prefetched_pages) * 4096);
        assert!(m.prefetch_hits + m.prefetch_wasted <= m.prefetched_pages);
    }

    #[test]
    fn fixed_policy_rounds_faults_up_to_groups() {
        let m = stream_run(PrefetchPolicy::Fixed);
        // 128 sequential pages = 8 groups of 16: one leader fault each
        // brings the other 15 along (modulo warp interleaving).
        assert!(m.faults < 128);
        assert!(m.prefetched_pages > 0);
        assert!(m.prefetch_hits > 0);
        assert_eq!(m.bytes_in, (m.faults + m.prefetched_pages) * 4096);
    }

    #[test]
    fn density_policy_promotes_dense_groups() {
        let m = stream_run(PrefetchPolicy::Density);
        assert!(m.prefetched_pages > 0, "dense stream must promote");
        assert!(m.faults < 128);
        assert!(m.prefetch_hits + m.prefetch_wasted <= m.prefetched_pages);
    }

    #[test]
    fn transports_swap_under_the_runtime() {
        // The same GPU-driven protocol over each engine: all complete,
        // conserve bytes, and land at their engine's latency point.
        let base = cfg(PrefetchPolicy::None);
        let run_with = |name: &str| {
            let mut c = base.clone();
            c.gpuvm.transport = name.to_string();
            let mut w = Stream::new(2, 64);
            let mut mem = GpuVmSystem::new(&c);
            run(&c, &mut w, &mut mem).unwrap().metrics
        };
        let rdma = run_with("rdma");
        let nvl = run_with("nvlink");
        let dma = run_with("pcie-dma");
        for (name, m) in [("rdma", &rdma), ("nvlink", &nvl), ("pcie-dma", &dma)] {
            assert_eq!(m.faults, 128, "{name}");
            assert_eq!(
                m.transport.bytes_moved,
                m.bytes_in + m.bytes_out,
                "{name} must conserve bytes"
            );
            assert_eq!(m.transport.wrs_serviced, m.work_requests, "{name}");
        }
        // A µs-class peer link beats the 23 µs verb floor end to end.
        assert!(
            nvl.finish_ns < rdma.finish_ns,
            "nvlink {} !< rdma {}",
            nvl.finish_ns,
            rdma.finish_ns
        );
        assert_eq!(rdma.transport.per_engine[0].name, "nic0");
        assert_eq!(nvl.transport.per_engine[0].name, "nvlink0");
    }

    #[test]
    fn residency_policies_swap_under_the_runtime() {
        use crate::residency::ResidencyPolicyKind;
        // Working set 512 KB, GPU memory 128 KB: every policy must keep
        // the run terminating with exact byte accounting and intact
        // pool invariants under heavy eviction churn.
        for kind in ResidencyPolicyKind::all() {
            let mut c = cfg(PrefetchPolicy::None);
            c.gpu.mem_bytes = 128 << 10;
            c.gpuvm.residency_policy = kind;
            let mut w = Stream::new(2, 64);
            let mut mem = GpuVmSystem::new(&c);
            let r = run(&c, &mut w, &mut mem).unwrap();
            mem.check_invariants().unwrap();
            let m = &r.metrics;
            assert_eq!(m.bytes_in, m.faults * 4096, "{kind:?}");
            assert_eq!(
                m.evictions,
                m.evictions_clean + m.evictions_dirty,
                "{kind:?}"
            );
            assert_eq!(m.evictions_dirty, 0, "{kind:?}: read-only stream");
            assert!(m.evictions > 0, "{kind:?} must evict under pressure");
        }
    }

    #[test]
    fn default_policy_telemetry_counts_thrash() {
        // Two passes over a working set 4× GPU memory: the second pass
        // refetches pages the first pass evicted, at short reuse
        // distance.
        let mut c = cfg(PrefetchPolicy::None);
        c.gpu.mem_bytes = 128 << 10;
        struct TwoPass {
            region: Option<RegionId>,
            kernel: u32,
            step: usize,
            pages: usize,
        }
        impl Workload for TwoPass {
            fn name(&self) -> &str {
                "two-pass"
            }
            fn setup(&mut self, hm: &mut HostMemory) {
                self.region = Some(hm.register("d", self.pages as u64 * 4096));
            }
            fn next_kernel(&mut self) -> Option<Launch> {
                self.kernel += 1;
                self.step = 0;
                (self.kernel <= 2).then_some(Launch { warps: 1, tag: 0 })
            }
            fn next_op(&mut self, _w: usize) -> WarpOp {
                let s = self.step;
                self.step += 1;
                if s >= self.pages {
                    return WarpOp::Done;
                }
                WarpOp::Access(vec![Access::Seq {
                    region: self.region.unwrap(),
                    start: (s as u64) * 4096,
                    len: 4096,
                    write: false,
                }])
            }
        }
        // 80 pages over 32 frames: a page evicted in pass 1 is refaulted
        // ~48 fills later — inside the 64-fill thrash window.
        let mut w = TwoPass {
            region: None,
            kernel: 0,
            step: 0,
            pages: 80,
        };
        let mut mem = GpuVmSystem::new(&c);
        let r = run(&c, &mut w, &mut mem).unwrap();
        let m = &r.metrics;
        assert!(m.refetches > 0, "second pass must refetch");
        assert!(
            m.thrash_refetches > 0,
            "32-frame pool over 80 sequential pages is textbook thrash"
        );
        assert!(m.thrash_refetches <= m.refetches);
        assert_eq!(m.reuse_distance.count(), m.refetches);
    }

    #[test]
    fn speculation_survives_oversubscription() {
        // Working set 512 KB, GPU memory 128 KB: heavy eviction churn
        // must keep accounting consistent and the run terminating.
        for policy in PrefetchPolicy::all() {
            let mut c = cfg(policy);
            c.gpu.mem_bytes = 128 << 10;
            let mut w = Stream::new(2, 64);
            let mut mem = GpuVmSystem::new(&c);
            let r = run(&c, &mut w, &mut mem).unwrap();
            mem.check_invariants().unwrap();
            let m = &r.metrics;
            assert_eq!(
                m.bytes_in,
                (m.faults + m.prefetched_pages) * 4096,
                "{policy:?}"
            );
            assert!(
                m.prefetch_hits + m.prefetch_wasted <= m.prefetched_pages,
                "{policy:?}: {} + {} > {}",
                m.prefetch_hits,
                m.prefetch_wasted,
                m.prefetched_pages
            );
        }
    }
}
