//! GPUVM: the paper's GPU-driven virtual memory runtime.

pub mod runtime;

pub use runtime::GpuVmSystem;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::residency::ResidencyPolicyKind;
    use crate::gpu::exec::run;
    use crate::gpu::kernel::{Access, Launch, WarpOp, Workload};
    use crate::mem::{HostMemory, RegionId};
    use crate::memsys::MemorySystem;

    /// Streaming reader: `warps` warps, each reads `reads` consecutive
    /// 128 B chunks spaced a page apart (forcing one fault per read when
    /// cold), with a compute step between.
    struct Reader {
        warps: usize,
        reads: usize,
        region: Option<RegionId>,
        launched: bool,
        state: Vec<(usize, bool)>, // (reads done, last was access)
        page_size: u64,
    }

    impl Reader {
        fn new(warps: usize, reads: usize, page_size: u64) -> Self {
            Self {
                warps,
                reads,
                region: None,
                launched: false,
                state: vec![(0, false); warps],
                page_size,
            }
        }
    }

    impl Workload for Reader {
        fn name(&self) -> &str {
            "reader"
        }
        fn setup(&mut self, hm: &mut HostMemory) {
            let bytes = (self.warps * self.reads) as u64 * self.page_size;
            self.region = Some(hm.register("data", bytes));
        }
        fn next_kernel(&mut self) -> Option<Launch> {
            if self.launched {
                return None;
            }
            self.launched = true;
            Some(Launch {
                warps: self.warps,
                tag: 0,
            })
        }
        fn next_op(&mut self, warp: usize) -> WarpOp {
            let (done, was_access) = self.state[warp];
            if was_access {
                self.state[warp].1 = false;
                return WarpOp::Compute { ops: 64 };
            }
            if done >= self.reads {
                return WarpOp::Done;
            }
            self.state[warp] = (done + 1, true);
            let page_idx = (warp * self.reads + done) as u64;
            WarpOp::Access(vec![Access::Seq {
                region: self.region.unwrap(),
                start: page_idx * self.page_size,
                len: 128,
                write: false,
            }])
        }
    }

    fn cfg(warps: usize, frames: u64) -> SystemConfig {
        let mut c = SystemConfig::default();
        c.gpu.sms = warps;
        c.gpu.warps_per_sm = 1;
        c.gpuvm.page_size = 4096;
        c.gpu.mem_bytes = frames * 4096;
        c.gpuvm.num_qps = 16;
        c
    }

    #[test]
    fn cold_faults_then_completion() {
        let c = cfg(4, 64);
        let mut w = Reader::new(4, 4, 4096);
        let mut mem = GpuVmSystem::new(&c);
        let r = run(&c, &mut w, &mut mem).unwrap();
        // 16 distinct pages, all cold: 16 leader faults, no coalescing.
        assert_eq!(r.metrics.faults, 16);
        assert_eq!(r.metrics.coalesced_faults, 0);
        assert_eq!(r.metrics.bytes_in, 16 * 4096);
        assert_eq!(r.metrics.evictions, 0);
        mem.check_invariants().unwrap();
        // Unloaded fault ≈ verb latency floor.
        let mean = r.metrics.fault_latency.mean_ns();
        assert!(
            (20_000.0..40_000.0).contains(&mean),
            "fault latency mean {mean}"
        );
    }

    /// All warps read the SAME page: one leader fault, rest coalesced.
    struct SamePage {
        warps: usize,
        region: Option<RegionId>,
        launched: bool,
        step: Vec<u8>,
    }

    impl Workload for SamePage {
        fn name(&self) -> &str {
            "same-page"
        }
        fn setup(&mut self, hm: &mut HostMemory) {
            self.region = Some(hm.register("one", 4096));
        }
        fn next_kernel(&mut self) -> Option<Launch> {
            if self.launched {
                return None;
            }
            self.launched = true;
            Some(Launch {
                warps: self.warps,
                tag: 0,
            })
        }
        fn next_op(&mut self, warp: usize) -> WarpOp {
            let s = self.step[warp];
            self.step[warp] += 1;
            match s {
                0 => WarpOp::Access(vec![Access::Seq {
                    region: self.region.unwrap(),
                    start: 0,
                    len: 64,
                    write: false,
                }]),
                _ => WarpOp::Done,
            }
        }
    }

    #[test]
    fn inter_warp_coalescing() {
        let c = cfg(8, 16);
        let mut w = SamePage {
            warps: 8,
            region: None,
            launched: false,
            step: vec![0; 8],
        };
        let mut mem = GpuVmSystem::new(&c);
        let r = run(&c, &mut w, &mut mem).unwrap();
        assert_eq!(r.metrics.faults, 1, "one leader");
        assert_eq!(r.metrics.coalesced_faults, 7, "seven join the in-flight fault");
        assert_eq!(r.metrics.bytes_in, 4096, "page transferred once");
    }

    #[test]
    fn oversubscription_evicts_fifo_and_preserves_liveness() {
        // 4 warps × 8 pages = 32 distinct pages through 8 frames.
        let c = cfg(4, 8);
        let mut w = Reader::new(4, 8, 4096);
        let mut mem = GpuVmSystem::new(&c);
        let r = run(&c, &mut w, &mut mem).unwrap();
        assert_eq!(r.metrics.faults, 32);
        assert!(r.metrics.evictions >= 24, "evictions={}", r.metrics.evictions);
        assert_eq!(r.metrics.refetches, 0, "streaming never refetches");
        mem.check_invariants().unwrap();
    }

    #[test]
    fn dirty_pages_write_back() {
        /// Write a page then stream far past it so it must evict.
        struct Writer {
            region: Option<RegionId>,
            launched: bool,
            step: usize,
        }
        impl Workload for Writer {
            fn name(&self) -> &str {
                "writer"
            }
            fn setup(&mut self, hm: &mut HostMemory) {
                self.region = Some(hm.register("w", 64 * 4096));
            }
            fn next_kernel(&mut self) -> Option<Launch> {
                if self.launched {
                    return None;
                }
                self.launched = true;
                Some(Launch { warps: 1, tag: 0 })
            }
            fn next_op(&mut self, _w: usize) -> WarpOp {
                let s = self.step;
                self.step += 1;
                if s == 0 {
                    WarpOp::Access(vec![Access::Seq {
                        region: self.region.unwrap(),
                        start: 0,
                        len: 128,
                        write: true,
                    }])
                } else if s <= 32 {
                    WarpOp::Access(vec![Access::Seq {
                        region: self.region.unwrap(),
                        start: (s as u64) * 4096,
                        len: 128,
                        write: false,
                    }])
                } else {
                    WarpOp::Done
                }
            }
        }
        let c = cfg(1, 8);
        let mut w = Writer {
            region: None,
            launched: false,
            step: 0,
        };
        let mut mem = GpuVmSystem::new(&c);
        let r = run(&c, &mut w, &mut mem).unwrap();
        assert!(r.metrics.bytes_out >= 4096, "dirty page written back");
        assert!(r.metrics.evictions > 0);
    }

    #[test]
    fn backed_mode_moves_real_bytes() {
        /// One warp reads one page of known data.
        struct ReadOne {
            region: Option<RegionId>,
            launched: bool,
            step: usize,
        }
        impl Workload for ReadOne {
            fn name(&self) -> &str {
                "read-one"
            }
            fn setup(&mut self, hm: &mut HostMemory) {
                let vals: Vec<f32> = (0..1024).map(|i| i as f32).collect();
                self.region = Some(hm.register_f32("d", &vals));
            }
            fn next_kernel(&mut self) -> Option<Launch> {
                if self.launched {
                    return None;
                }
                self.launched = true;
                Some(Launch { warps: 1, tag: 0 })
            }
            fn next_op(&mut self, _w: usize) -> WarpOp {
                self.step += 1;
                if self.step == 1 {
                    WarpOp::Access(vec![Access::Seq {
                        region: self.region.unwrap(),
                        start: 0,
                        len: 4096,
                        write: false,
                    }])
                } else {
                    WarpOp::Done
                }
            }
        }
        let c = cfg(1, 8);
        let mut w = ReadOne {
            region: None,
            launched: false,
            step: 0,
        };
        let mut mem = GpuVmSystem::with_backing(&c, true);
        let _r = run(&c, &mut w, &mut mem).unwrap();
        // After the run the page streamed through frame 0: verify bytes.
        let bytes = mem.pool(0).frame_bytes(crate::mem::FrameId(0)).unwrap();
        let v1 = f32::from_le_bytes(bytes[4..8].try_into().unwrap());
        assert_eq!(v1, 1.0, "frame holds the host page's bytes");
    }

    #[test]
    fn eviction_policies_all_complete() {
        for policy in ResidencyPolicyKind::all() {
            let mut c = cfg(4, 8);
            c.gpuvm.residency_policy = policy;
            let mut w = Reader::new(4, 8, 4096);
            let mut mem = GpuVmSystem::new(&c);
            let r = run(&c, &mut w, &mut mem).unwrap();
            assert_eq!(r.metrics.faults, 32, "{policy:?}");
            mem.check_invariants().unwrap();
        }
    }

    #[test]
    fn batching_reduces_doorbells() {
        let mut c1 = cfg(8, 256);
        c1.gpuvm.fault_batch = 1;
        let mut c4 = cfg(8, 256);
        c4.gpuvm.fault_batch = 4;
        let mut w1 = Reader::new(8, 16, 4096);
        let mut w4 = Reader::new(8, 16, 4096);
        let mut m1 = GpuVmSystem::new(&c1);
        let mut m4 = GpuVmSystem::new(&c4);
        let r1 = run(&c1, &mut w1, &mut m1).unwrap();
        let r4 = run(&c4, &mut w4, &mut m4).unwrap();
        assert_eq!(r1.metrics.work_requests, r4.metrics.work_requests);
        assert!(
            r4.metrics.doorbells < r1.metrics.doorbells,
            "batched doorbells {} !< unbatched {}",
            r4.metrics.doorbells,
            r1.metrics.doorbells
        );
    }

    #[test]
    fn name_and_finalize() {
        let c = cfg(2, 8);
        let mut mem = GpuVmSystem::new(&c);
        assert_eq!(MemorySystem::name(&mem), "gpuvm");
        let mut w = Reader::new(2, 2, 4096);
        let r = run(&c, &mut w, &mut mem).unwrap();
        assert!(r.metrics.counter("nic_wrs") >= 4);
        assert!(r.metrics.link_busy_ns.contains_key("nic0"));
    }
}
