//! # GPUVM — GPU-driven Unified Virtual Memory (reproduction)
//!
//! A full-system reproduction of *GPUVM: GPU-driven Unified Virtual
//! Memory* (Nazaraliyev, Sadredini, Abu-Ghazaleh; CS.DC 2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's contribution as a calibrated
//!   functional + timing simulation: GPU warps handle their own page
//!   faults by posting RDMA work requests to RNIC queue pairs; a FIFO
//!   circular page buffer with reference counters manages GPU memory;
//!   a UVM model (OS fault handler, 64 KB prefetch, 2 MB VABlock
//!   eviction) and bulk-transfer baselines (GPUDirect, Subway, a
//!   RAPIDS-like scan engine) provide every comparison the paper makes.
//! - **L2/L1 (python/, build-time only)** — the per-page compute payloads
//!   as JAX graphs over Pallas kernels, AOT-lowered to HLO text.
//! - **runtime/** — loads those artifacts via the PJRT C API (`xla`
//!   crate, behind the `xla` feature; offline builds get a stub) and
//!   executes them from the Rust hot path; Python never runs at request
//!   time.
//!
//! Entry points: the [`coordinator::Session`] builder constructs single
//! runs and multi-threaded sweeps over any registered
//! [`coordinator::Backend`] (`gpuvm`, `uvm`, `uvm-memadvise`, `ideal`,
//! `gdr`, `subway`, `rapids`); the `gpuvm` binary wraps it as
//! `run`/`compare`/`sweep`. See the top-level `README.md` for a
//! quickstart and the experiment index (`rust/benches/` reproduces every
//! figure and table).

pub mod analyze;
pub mod apps;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod fabric;
pub mod gpu;
pub mod graph;
pub mod gpuvm;
pub mod mem;
pub mod memsys;
pub mod metrics;
pub mod obs;
pub mod pcie;
pub mod prefetch;
pub mod residency;
pub mod rnic;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;
pub mod uvm;
