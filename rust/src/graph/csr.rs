//! Compressed Sparse Row graph representation.

/// A directed graph in CSR form. `offsets[v]..offsets[v+1]` indexes into
/// `neighbors` (and `weights`, when present).
#[derive(Debug, Clone)]
pub struct Csr {
    pub num_vertices: usize,
    pub offsets: Vec<u64>,
    pub neighbors: Vec<u32>,
    pub weights: Option<Vec<f32>>,
}

impl Csr {
    /// Build from an edge list (u → v). Parallel edges are kept (as in
    /// the SuiteSparse dumps the paper uses); self-loops allowed.
    pub fn from_edges(num_vertices: usize, edges: &[(u32, u32)]) -> Self {
        let mut deg = vec![0u64; num_vertices];
        for &(u, _) in edges {
            deg[u as usize] += 1;
        }
        let mut offsets = vec![0u64; num_vertices + 1];
        for v in 0..num_vertices {
            offsets[v + 1] = offsets[v] + deg[v];
        }
        let mut cursor = offsets.clone();
        let mut neighbors = vec![0u32; edges.len()];
        for &(u, v) in edges {
            let c = &mut cursor[u as usize];
            neighbors[*c as usize] = v;
            *c += 1;
        }
        Self {
            num_vertices,
            offsets,
            neighbors,
            weights: None,
        }
    }

    /// Attach uniform-random weights in `[1, 64)` (SSSP inputs).
    pub fn with_weights(mut self, rng: &mut crate::util::rng::Rng) -> Self {
        self.weights = Some(
            (0..self.neighbors.len())
                .map(|_| 1.0 + rng.f64() as f32 * 63.0)
                .collect(),
        );
        self
    }

    pub fn num_edges(&self) -> usize {
        self.neighbors.len()
    }

    #[inline]
    pub fn degree(&self, v: usize) -> u64 {
        self.offsets[v + 1] - self.offsets[v]
    }

    pub fn max_degree(&self) -> u64 {
        (0..self.num_vertices).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    #[inline]
    pub fn neighbors_of(&self, v: usize) -> &[u32] {
        &self.neighbors[self.offsets[v] as usize..self.offsets[v + 1] as usize]
    }

    /// Bytes of the edge structure (neighbors array), as reported in the
    /// paper's Table 2 "Edges" column.
    pub fn edge_bytes(&self) -> u64 {
        (self.neighbors.len() * 4) as u64
    }

    /// Bytes including weights, Table 2's "Weights" column.
    pub fn weight_bytes(&self) -> u64 {
        self.weights.as_ref().map_or(0, |w| (w.len() * 4) as u64)
    }

    /// Pick `n` source vertices with degree ≥ `min_degree` (the paper
    /// runs BFS/SSSP from >100 sources with ≥2 neighbors).
    pub fn pick_sources(
        &self,
        n: usize,
        min_degree: u64,
        rng: &mut crate::util::rng::Rng,
    ) -> Vec<u32> {
        let mut sources = Vec::with_capacity(n);
        let mut tries = 0;
        while sources.len() < n && tries < n * 1000 {
            tries += 1;
            let v = rng.gen_range(self.num_vertices as u64) as u32;
            if self.degree(v as usize) >= min_degree {
                sources.push(v);
            }
        }
        sources
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn diamond() -> Csr {
        // 0→1, 0→2, 1→3, 2→3
        Csr::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)])
    }

    #[test]
    fn structure() {
        let g = diamond();
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors_of(0), &[1, 2]);
        assert_eq!(g.neighbors_of(1), &[3]);
        assert_eq!(g.neighbors_of(3), &[] as &[u32]);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.max_degree(), 2);
        assert_eq!(g.edge_bytes(), 16);
    }

    #[test]
    fn weights_attach() {
        let mut rng = Rng::new(1);
        let g = diamond().with_weights(&mut rng);
        let w = g.weights.as_ref().unwrap();
        assert_eq!(w.len(), 4);
        assert!(w.iter().all(|&x| (1.0..64.0).contains(&x)));
        assert_eq!(g.weight_bytes(), 16);
    }

    #[test]
    fn sources_respect_min_degree() {
        let g = diamond();
        let mut rng = Rng::new(2);
        let s = g.pick_sources(10, 2, &mut rng);
        assert!(s.iter().all(|&v| g.degree(v as usize) >= 2));
        assert!(s.iter().all(|&v| v == 0)); // only vertex 0 has degree 2
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edges(3, &[]);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.max_degree(), 0);
    }
}
