//! Reference (host-side, scalar) graph algorithms. These are the ground
//! truth the simulated GPU apps verify against, and they drive the
//! frontier progression that the Subway baseline and the iterative
//! kernels share.

use super::csr::Csr;
use std::collections::VecDeque;

pub const UNREACHED: u32 = u32::MAX;

/// BFS levels from `src` (UNREACHED where not reachable).
pub fn bfs_levels(g: &Csr, src: u32) -> Vec<u32> {
    let mut level = vec![UNREACHED; g.num_vertices];
    let mut q = VecDeque::new();
    level[src as usize] = 0;
    q.push_back(src);
    while let Some(u) = q.pop_front() {
        let next = level[u as usize] + 1;
        for &v in g.neighbors_of(u as usize) {
            if level[v as usize] == UNREACHED {
                level[v as usize] = next;
                q.push_back(v);
            }
        }
    }
    level
}

/// Per-level frontiers from `src` (frontier[k] = vertices at distance k).
pub fn bfs_frontiers(g: &Csr, src: u32) -> Vec<Vec<u32>> {
    let levels = bfs_levels(g, src);
    let max = levels
        .iter()
        .filter(|&&l| l != UNREACHED)
        .max()
        .copied()
        .unwrap_or(0);
    let mut fronts = vec![Vec::new(); max as usize + 1];
    for (v, &l) in levels.iter().enumerate() {
        if l != UNREACHED {
            fronts[l as usize].push(v as u32);
        }
    }
    fronts
}

/// Connected components by label propagation over the *undirected* view
/// (min label wins), as GPU CC implementations do. Returns labels and the
/// number of propagation iterations until fixpoint.
pub fn cc_labels(g: &Csr) -> (Vec<u32>, usize) {
    let (labels, rounds) = cc_rounds(g);
    (labels, rounds.len())
}

/// Label propagation with per-round *active sets*: round k processes the
/// vertices whose label changed in round k-1 (round 0 = all). This is
/// how GPU CC kernels and Subway bound per-iteration work — the active
/// set shrinks geometrically after the first rounds.
pub fn cc_rounds(g: &Csr) -> (Vec<u32>, Vec<Vec<u32>>) {
    let mut label: Vec<u32> = (0..g.num_vertices as u32).collect();
    let mut active: Vec<u32> = (0..g.num_vertices as u32).collect();
    let mut rounds = Vec::new();
    while !active.is_empty() {
        rounds.push(active.clone());
        let mut changed = vec![false; g.num_vertices];
        for &u in &active {
            let u = u as usize;
            for &v in g.neighbors_of(u) {
                let (lu, lv) = (label[u], label[v as usize]);
                if lu < lv {
                    label[v as usize] = lu;
                    changed[v as usize] = true;
                } else if lv < lu {
                    label[u] = lv;
                    changed[u] = true;
                }
            }
        }
        active = changed
            .iter()
            .enumerate()
            .filter_map(|(v, &c)| c.then_some(v as u32))
            .collect();
        if rounds.len() > g.num_vertices {
            break; // safety
        }
    }
    (label, rounds)
}

/// Single-source shortest paths (Bellman-Ford frontier style). Returns
/// distances (f32::INFINITY where unreachable) and the per-iteration
/// frontier sizes (for iterative kernel simulation).
pub fn sssp(g: &Csr, src: u32) -> (Vec<f32>, Vec<usize>) {
    let w = g
        .weights
        .as_ref()
        .expect("sssp requires weights");
    let mut dist = vec![f32::INFINITY; g.num_vertices];
    dist[src as usize] = 0.0;
    let mut frontier = vec![src];
    let mut sizes = Vec::new();
    while !frontier.is_empty() {
        sizes.push(frontier.len());
        let mut next = Vec::new();
        let mut in_next = vec![false; g.num_vertices];
        for &u in &frontier {
            let (s, e) = (g.offsets[u as usize] as usize, g.offsets[u as usize + 1] as usize);
            for i in s..e {
                let v = g.neighbors[i] as usize;
                let nd = dist[u as usize] + w[i];
                if nd < dist[v] {
                    dist[v] = nd;
                    if !in_next[v] {
                        in_next[v] = true;
                        next.push(v as u32);
                    }
                }
            }
        }
        frontier = next;
        if sizes.len() > 10 * g.num_vertices {
            break; // safety (negative weights are impossible here)
        }
    }
    (dist, sizes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn chain() -> Csr {
        // 0→1→2→3 plus isolated 4
        Csr::from_edges(5, &[(0, 1), (1, 2), (2, 3)])
    }

    #[test]
    fn bfs_chain() {
        let g = chain();
        let l = bfs_levels(&g, 0);
        assert_eq!(l, vec![0, 1, 2, 3, UNREACHED]);
        let f = bfs_frontiers(&g, 0);
        assert_eq!(f.len(), 4);
        assert_eq!(f[2], vec![2]);
    }

    #[test]
    fn cc_two_components() {
        let g = Csr::from_edges(5, &[(0, 1), (1, 2), (3, 4)]);
        let (l, iters) = cc_labels(&g);
        assert_eq!(l[0], l[1]);
        assert_eq!(l[1], l[2]);
        assert_eq!(l[3], l[4]);
        assert_ne!(l[0], l[3]);
        assert!(iters >= 1);
    }

    #[test]
    fn sssp_prefers_cheap_path() {
        // 0→1 (w 10), 0→2 (w 1), 2→1 (w 1): dist(1) = 2 via 2.
        let mut g = Csr::from_edges(3, &[(0, 1), (0, 2), (2, 1)]);
        g.weights = Some(vec![10.0, 1.0, 1.0]);
        let (d, sizes) = sssp(&g, 0);
        assert_eq!(d[0], 0.0);
        assert_eq!(d[2], 1.0);
        assert_eq!(d[1], 2.0);
        assert!(!sizes.is_empty());
    }

    #[test]
    fn bfs_matches_sssp_on_unit_weights() {
        let mut rng = Rng::new(5);
        let edges: Vec<(u32, u32)> = (0..2000)
            .map(|_| (rng.gen_range(100) as u32, rng.gen_range(100) as u32))
            .collect();
        let mut g = Csr::from_edges(100, &edges);
        g.weights = Some(vec![1.0; g.num_edges()]);
        let l = bfs_levels(&g, 0);
        let (d, _) = sssp(&g, 0);
        for v in 0..100 {
            if l[v] == UNREACHED {
                assert!(d[v].is_infinite());
            } else {
                assert_eq!(d[v], l[v] as f32);
            }
        }
    }
}
