//! The paper's graph datasets (Table 2), scaled ~1000× down with their
//! shape parameters preserved. Sizes are chosen so the default benches
//! run in seconds; `scale` lets the benches grow them.

use super::csr::Csr;
use super::gen;
use crate::util::rng::Rng;

/// Which Table 2 graph a scaled instance mimics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetId {
    /// GAP-Urand: uniform, flat degrees.
    GU,
    /// GAP-Kron: Kronecker, extreme hubs (paper max degree ≈ 7.5 M).
    GK,
    /// Friendster: community structure (paper max degree 5 200).
    FS,
    /// MOLIERE: dense biomedical co-occurrence, heavy hubs (≈ 2.1 M),
    /// highest edge/vertex ratio and > 2^32-edge-class size (Subway
    /// cannot run it — Table 3 note).
    MO,
}

impl DatasetId {
    pub fn abbr(&self) -> &'static str {
        match self {
            DatasetId::GU => "GU",
            DatasetId::GK => "GK",
            DatasetId::FS => "FS",
            DatasetId::MO => "MO",
        }
    }

    pub fn all() -> [DatasetId; 4] {
        [DatasetId::GU, DatasetId::GK, DatasetId::FS, DatasetId::MO]
    }

    /// Parse a Table 2 abbreviation (used by workload specs like `bfs:GK`).
    pub fn parse(s: &str) -> anyhow::Result<DatasetId> {
        Ok(match s {
            "GU" => DatasetId::GU,
            "GK" => DatasetId::GK,
            "FS" => DatasetId::FS,
            "MO" => DatasetId::MO,
            _ => anyhow::bail!("unknown dataset '{s}' (GU|GK|FS|MO)"),
        })
    }

    /// Table 3 runs only GK/GU/FS (Subway's 2^32 vertex-id limit).
    pub fn subway_supported(&self) -> bool {
        !matches!(self, DatasetId::MO)
    }
}

/// A generated, weighted instance plus its provenance.
pub struct Dataset {
    pub id: DatasetId,
    pub graph: Csr,
}

/// Generate a scaled instance. `scale = 1.0` gives the default bench
/// size (~0.5–1 M edges); paper-relative vertex/edge ratios are kept.
pub fn generate(id: DatasetId, scale: f64, seed: u64) -> Dataset {
    // (vertices, edges) at scale 1.0 — ratios follow Table 2:
    // GU/GK: |E|/|V| = 32; FS: 55; MO: 221.
    let (v, e) = match id {
        DatasetId::GU => (32_768, 1_048_576),
        DatasetId::GK => (32_768, 1_048_576),
        DatasetId::FS => (16_384, 901_120),
        DatasetId::MO => (7_424, 1_638_400),
    };
    let v = ((v as f64 * scale) as usize).max(64);
    let e = ((e as f64 * scale) as usize).max(256);
    let mut rng = Rng::new(seed ^ (id.abbr().len() as u64) << 32 ^ id as u64);
    let graph = match id {
        DatasetId::GU => gen::uniform(v, e, rng.next_u64()),
        DatasetId::GK => gen::rmat(v, e, rng.next_u64()),
        DatasetId::FS => gen::community(v, e, (v / 300).max(4), 0.75, rng.next_u64()),
        DatasetId::MO => gen::rmat_with(v, e, 0.62, 0.17, 0.17, rng.next_u64()),
    };
    let graph = graph.with_weights(&mut rng);
    Dataset { id, graph }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_follow_table2() {
        let gu = generate(DatasetId::GU, 0.25, 1);
        let gk = generate(DatasetId::GK, 0.25, 1);
        let fs = generate(DatasetId::FS, 0.25, 1);
        let mo = generate(DatasetId::MO, 0.25, 1);
        // Degree skew ordering: GU flat; GK/MO extreme; FS in between.
        assert!(gu.graph.max_degree() < 100, "GU max {}", gu.graph.max_degree());
        assert!(
            gk.graph.max_degree() > 10 * fs.graph.max_degree().max(1) / 2,
            "GK {} vs FS {}",
            gk.graph.max_degree(),
            fs.graph.max_degree()
        );
        assert!(mo.graph.max_degree() > gu.graph.max_degree() * 10);
        // MO has the highest density.
        let density = |d: &Dataset| d.graph.num_edges() as f64 / d.graph.num_vertices as f64;
        assert!(density(&mo) > density(&gu) * 3.0);
        // All weighted.
        assert!(gu.graph.weights.is_some());
    }

    #[test]
    fn subway_support_flag() {
        assert!(DatasetId::GK.subway_supported());
        assert!(!DatasetId::MO.subway_supported());
    }

    #[test]
    fn scaling() {
        let small = generate(DatasetId::GU, 0.1, 1);
        let big = generate(DatasetId::GU, 0.5, 1);
        assert!(big.graph.num_edges() > 4 * small.graph.num_edges());
    }
}
