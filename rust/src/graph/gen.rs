//! Synthetic graph generators with the *shape* of the paper's datasets
//! (Table 2), scaled ~1000× down. What matters for the reproduction is
//! degree skew: GAP-Urand is flat (max degree ~68 at 4.3 B edges);
//! GAP-Kron and MOLIERE have enormous hubs (7.5 M / 2.1 M neighbors) that
//! serialize page faults on a single warp; Friendster sits in between
//! with community structure (max degree 5 200).

use super::csr::Csr;
use crate::util::rng::Rng;

/// Erdős–Rényi-style uniform graph (GAP-Urand shape).
pub fn uniform(num_vertices: usize, num_edges: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let v = num_vertices as u64;
    let edges: Vec<(u32, u32)> = (0..num_edges)
        .map(|_| (rng.gen_range(v) as u32, rng.gen_range(v) as u32))
        .collect();
    Csr::from_edges(num_vertices, &edges)
}

/// RMAT/Kronecker generator (GAP-Kron / MOLIERE shape). Standard
/// parameters (a,b,c) = (0.57, 0.19, 0.19) give the heavy skew.
pub fn rmat(num_vertices: usize, num_edges: usize, seed: u64) -> Csr {
    rmat_with(num_vertices, num_edges, 0.57, 0.19, 0.19, seed)
}

pub fn rmat_with(
    num_vertices: usize,
    num_edges: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
) -> Csr {
    assert!(a + b + c < 1.0);
    let scale = (num_vertices as f64).log2().ceil() as u32;
    let n = 1usize << scale;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f64();
            let (du, dv) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        edges.push(((u % num_vertices) as u32, (v % num_vertices) as u32));
    }
    let _ = n;
    Csr::from_edges(num_vertices, &edges)
}

/// Community graph (Friendster shape): vertices grouped into communities;
/// most edges intra-community, a Zipf-skewed fraction across.
pub fn community(
    num_vertices: usize,
    num_edges: usize,
    num_communities: usize,
    p_intra: f64,
    seed: u64,
) -> Csr {
    assert!(num_communities > 0);
    let mut rng = Rng::new(seed);
    let csize = num_vertices.div_ceil(num_communities);
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        let u = rng.gen_range(num_vertices as u64) as usize;
        let v = if rng.bool(p_intra) {
            // Within u's community.
            let com = u / csize;
            let base = com * csize;
            let span = csize.min(num_vertices - base);
            base + rng.gen_range(span as u64) as usize
        } else {
            // Cross-community, Zipf-skewed toward popular vertices.
            rng.zipf(num_vertices as u64, 1.3) as usize
        };
        edges.push((u as u32, v as u32));
    }
    Csr::from_edges(num_vertices, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_has_flat_degrees() {
        let g = uniform(10_000, 100_000, 42);
        assert_eq!(g.num_edges(), 100_000);
        // Poisson(10): max degree stays small, like GAP-Urand's 68.
        assert!(g.max_degree() < 40, "max={}", g.max_degree());
    }

    #[test]
    fn rmat_has_hubs() {
        let g = rmat(10_000, 100_000, 42);
        assert_eq!(g.num_edges(), 100_000);
        // Kron-shaped graphs concentrate edges: hubs ≫ mean degree (10).
        assert!(g.max_degree() > 300, "max={}", g.max_degree());
    }

    #[test]
    fn community_in_between() {
        let g = community(10_000, 100_000, 50, 0.8, 42);
        assert_eq!(g.num_edges(), 100_000);
        let max = g.max_degree();
        assert!(max > 20 && max < 3000, "max={max}");
    }

    #[test]
    fn generators_deterministic() {
        let a = rmat(1000, 5000, 7);
        let b = rmat(1000, 5000, 7);
        assert_eq!(a.neighbors, b.neighbors);
        let c = rmat(1000, 5000, 8);
        assert_ne!(a.neighbors, c.neighbors);
    }
}
