//! Balanced CSR (paper Fig 10): edges regrouped into equal-size chunks so
//! every worker (warp) gets the same amount of edge work and therefore a
//! fairly equal number of page faults — the fix for fault serialization
//! on high-degree hubs (GK's 7.5 M-neighbor vertex).

use super::csr::Csr;

/// One unit of work: a slice of a single vertex's neighbor list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    pub vertex: u32,
    /// Start index into the shared `neighbors` array.
    pub edge_start: u64,
    pub len: u32,
}

/// Balanced CSR: the same `neighbors`/`weights` arrays as the CSR, plus a
/// chunk table that splits every neighbor list into ≤ `chunk_size` pieces.
#[derive(Debug, Clone)]
pub struct BalancedCsr {
    pub chunk_size: u32,
    pub chunks: Vec<Chunk>,
}

impl BalancedCsr {
    pub fn build(csr: &Csr, chunk_size: u32) -> Self {
        assert!(chunk_size > 0);
        let mut chunks = Vec::new();
        for v in 0..csr.num_vertices {
            let start = csr.offsets[v];
            let end = csr.offsets[v + 1];
            let mut e = start;
            while e < end {
                let len = (end - e).min(chunk_size as u64) as u32;
                chunks.push(Chunk {
                    vertex: v as u32,
                    edge_start: e,
                    len,
                });
                e += len as u64;
            }
        }
        Self { chunk_size, chunks }
    }

    pub fn num_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Extra memory the chunk table costs (the paper: ≤ 400 MB for
    /// billion-edge graphs — negligible).
    pub fn overhead_bytes(&self) -> u64 {
        (self.chunks.len() * std::mem::size_of::<Chunk>()) as u64
    }

    /// Chunks owned by `vertex` (test helper).
    pub fn chunks_of(&self, vertex: u32) -> impl Iterator<Item = &Chunk> {
        self.chunks.iter().filter(move |c| c.vertex == vertex)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn splits_hubs_evenly() {
        // Vertex 0 has 10 edges, vertex 1 has 1, chunk size 4.
        let edges: Vec<(u32, u32)> = (0..10).map(|i| (0u32, i as u32 % 3)).chain([(1, 2)]).collect();
        let csr = Csr::from_edges(3, &edges);
        let b = BalancedCsr::build(&csr, 4);
        let v0: Vec<_> = b.chunks_of(0).collect();
        assert_eq!(v0.len(), 3); // 4 + 4 + 2
        assert_eq!(v0[0].len, 4);
        assert_eq!(v0[2].len, 2);
        assert_eq!(b.chunks_of(1).count(), 1);
        assert!(b.chunks.iter().all(|c| c.len <= 4));
    }

    #[test]
    fn covers_all_edges_exactly_once() {
        let mut rng = Rng::new(7);
        let edges: Vec<(u32, u32)> = (0..500)
            .map(|_| (rng.gen_range(40) as u32, rng.gen_range(40) as u32))
            .collect();
        let csr = Csr::from_edges(40, &edges);
        let b = BalancedCsr::build(&csr, 16);
        let total: u64 = b.chunks.iter().map(|c| c.len as u64).sum();
        assert_eq!(total, csr.num_edges() as u64);
        // Chunks of a vertex tile its CSR range contiguously.
        for v in 0..40u32 {
            let mut expect = csr.offsets[v as usize];
            for c in b.chunks_of(v) {
                assert_eq!(c.edge_start, expect);
                expect += c.len as u64;
            }
            assert_eq!(expect, csr.offsets[v as usize + 1]);
        }
    }

    #[test]
    fn overhead_is_small() {
        let edges: Vec<(u32, u32)> = (0..1000).map(|i| (i % 100, (i + 1) % 100)).collect();
        let csr = Csr::from_edges(100, &edges);
        let b = BalancedCsr::build(&csr, 32);
        assert!(b.overhead_bytes() < csr.edge_bytes());
    }
}
