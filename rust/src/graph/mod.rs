//! Graph substrate: CSR, Balanced CSR (Fig 10), reference algorithms,
//! generators, and the scaled Table 2 datasets.

pub mod algo;
pub mod balanced;
pub mod csr;
pub mod datasets;
pub mod gen;

pub use balanced::{BalancedCsr, Chunk};
pub use csr::Csr;
pub use datasets::{generate, Dataset, DatasetId};
