//! PCIe topology and bandwidth-contention model (paper Fig 7).
//!
//! The r7525 node: GPUs and RNICs hang off *separate* PCIe bridges under
//! the root complex; host DRAM is reached through the root. The NIC's
//! bridge is a shared channel, so a page flowing host-mem → NIC → GPU
//! crosses that bridge twice and usable one-directional bandwidth halves
//! (Fig 7 caption; the 6.5 GB/s ceiling of Fig 8). GPU bridges are modeled
//! full-duplex (separate up/down links).
//!
//! Contention model: each link is a FIFO byte-serial resource with a
//! `busy_until` horizon; a transfer reserves each link on its path in
//! order (store-and-forward). With many small concurrent transfers this
//! reduces to an M/D/1-ish queue per link, which is exactly the regime the
//! paper's Little's-law analysis (§3.2) describes.

use crate::config::SystemConfig;
use crate::sim::{ns_for_bytes, SimTime};

/// Index into the topology's link table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkId(pub usize);

#[derive(Debug, Clone)]
pub struct Link {
    pub name: String,
    /// Usable bandwidth, bytes/s.
    pub bw: f64,
    /// Earliest time the link is free.
    busy_until: SimTime,
    /// Accumulated busy nanoseconds (for utilization reporting).
    busy_ns: u64,
    /// Bytes carried.
    pub bytes: u64,
}

impl Link {
    fn new(name: impl Into<String>, bw: f64) -> Self {
        Self {
            name: name.into(),
            bw,
            busy_until: 0,
            busy_ns: 0,
            bytes: 0,
        }
    }
}

/// Direction of a transfer relative to the GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// host memory → GPU
    In,
    /// GPU → host memory
    Out,
}

/// The simulated PCIe fabric.
pub struct Topology {
    links: Vec<Link>,
    hop_ns: u64,
    mem: LinkId,
    /// one per NIC; shared channel (both directions) if `nic_bridge_shared`
    nic_bridge: Vec<LinkId>,
    /// per GPU: (down = toward GPU, up = from GPU)
    gpu_bridge: Vec<(LinkId, LinkId)>,
    nic_bridge_shared: bool,
    /// separate up-links for NIC bridges when not shared
    nic_bridge_up: Vec<LinkId>,
    /// per GPU: full-duplex NVLink peer channel (down, up) — aggregate
    /// bandwidth of `nvlink.num_links` links, used only by the `nvlink`
    /// transport.
    nvlink: Vec<(LinkId, LinkId)>,
}

impl Topology {
    pub fn new(cfg: &SystemConfig) -> Self {
        let mut links = Vec::new();
        let mut add = |name: String, bw: f64| {
            links.push(Link::new(name, bw));
            LinkId(links.len() - 1)
        };
        let mem = add("mem".into(), cfg.pcie.mem_bw);
        let mut nic_bridge = Vec::new();
        let mut nic_bridge_up = Vec::new();
        for n in 0..cfg.rnic.num_nics {
            nic_bridge.push(add(format!("nic{n}"), cfg.pcie.link_bw));
            if !cfg.pcie.nic_bridge_shared {
                nic_bridge_up.push(add(format!("nic{n}.up"), cfg.pcie.link_bw));
            }
        }
        let mut gpu_bridge = Vec::new();
        for g in 0..cfg.gpu.num_gpus {
            let down = add(format!("gpu{g}.down"), cfg.pcie.link_bw);
            let up = add(format!("gpu{g}.up"), cfg.pcie.link_bw);
            gpu_bridge.push((down, up));
        }
        let nvlink_bw = cfg.nvlink.num_links.max(1) as f64 * cfg.nvlink.link_bw;
        let mut nvlink = Vec::new();
        for g in 0..cfg.gpu.num_gpus {
            let down = add(format!("nvlink{g}.down"), nvlink_bw);
            let up = add(format!("nvlink{g}.up"), nvlink_bw);
            nvlink.push((down, up));
        }
        Self {
            links,
            hop_ns: cfg.pcie.hop_ns,
            mem,
            nic_bridge,
            gpu_bridge,
            nic_bridge_shared: cfg.pcie.nic_bridge_shared,
            nic_bridge_up,
            nvlink,
        }
    }

    pub fn num_nics(&self) -> usize {
        self.nic_bridge.len()
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    /// Busy-time accumulated on a link, ns.
    pub fn busy_ns(&self, id: LinkId) -> u64 {
        self.links[id.0].busy_ns
    }

    pub fn find_link(&self, name: &str) -> Option<LinkId> {
        self.links.iter().position(|l| l.name == name).map(LinkId)
    }

    /// Path for a page moved by RNIC `nic` for GPU `gpu`:
    /// mem → NIC bridge (ingress) → NIC bridge (egress) → GPU bridge.
    pub fn path_via_nic(&self, nic: usize, gpu: usize, dir: Dir) -> Vec<LinkId> {
        let nb_in = self.nic_bridge[nic];
        let nb_out = if self.nic_bridge_shared {
            self.nic_bridge[nic]
        } else {
            self.nic_bridge_up[nic]
        };
        let (down, up) = self.gpu_bridge[gpu];
        match dir {
            Dir::In => vec![self.mem, nb_in, nb_out, down],
            Dir::Out => vec![up, nb_in, nb_out, self.mem],
        }
    }

    /// Path for a direct host↔GPU DMA (the UVM / bulk-copy data path —
    /// no NIC in the loop).
    pub fn path_direct(&self, gpu: usize, dir: Dir) -> Vec<LinkId> {
        let (down, up) = self.gpu_bridge[gpu];
        match dir {
            Dir::In => vec![self.mem, down],
            Dir::Out => vec![up, self.mem],
        }
    }

    /// Path over GPU `gpu`'s NVLink peer channel (the `nvlink`
    /// transport's data path). The backing store is NVLink-attached
    /// remote memory — a peer GPU's HBM or an NVLink-connected host —
    /// so the path is the peer channel alone: the remote memory end is
    /// not the PCIe root-complex `mem` link and never bottlenecks it.
    pub fn path_nvlink(&self, gpu: usize, dir: Dir) -> Vec<LinkId> {
        let (down, up) = self.nvlink[gpu];
        match dir {
            Dir::In => vec![down],
            Dir::Out => vec![up],
        }
    }

    /// Reserve `bytes` across `path` starting no earlier than `now`;
    /// returns the delivery (finish) time. Each hop is store-and-forward:
    /// propagate (`hop_ns`, latency only — it does NOT occupy the link),
    /// queue behind the link's horizon, occupy it for bytes/bw, move on.
    pub fn transfer(&mut self, now: SimTime, bytes: u64, path: &[LinkId]) -> SimTime {
        let mut t = now;
        let mut prev: Option<usize> = None;
        for &LinkId(i) in path {
            let link = &mut self.links[i];
            // A doubly-crossed shared channel (NIC bridge) is one
            // contiguous occupancy: no propagation gap between the in-
            // and out-crossing, or the gap would be dead air on the wire.
            let ready = if prev == Some(i) {
                t
            } else {
                t.saturating_add(self.hop_ns)
            };
            let start = ready.max(link.busy_until);
            let dur = ns_for_bytes(bytes, link.bw);
            link.busy_until = start + dur;
            link.busy_ns += dur;
            link.bytes += bytes;
            t = start + dur;
            prev = Some(i);
        }
        t
    }

    /// Earliest time the first link of `path` frees up (for backpressure).
    pub fn free_at(&self, path: &[LinkId]) -> SimTime {
        path.first().map_or(0, |&LinkId(i)| self.links[i].busy_until)
    }

    /// Copy per-link busy counters into run metrics.
    pub fn export_utilization(&self, m: &mut crate::metrics::Metrics) {
        for l in &self.links {
            m.add_link_busy(&l.name, l.busy_ns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(nics: usize) -> SystemConfig {
        let mut c = SystemConfig::default();
        c.rnic.num_nics = nics;
        c.pcie.hop_ns = 0; // simplify math in tests
        c
    }

    #[test]
    fn nic_path_crosses_bridge_twice() {
        let c = cfg(1);
        let topo = Topology::new(&c);
        let path = topo.path_via_nic(0, 0, Dir::In);
        let nic = topo.find_link("nic0").unwrap();
        let crossings = path.iter().filter(|&&l| l == nic).count();
        assert_eq!(crossings, 2, "shared bridge must be traversed twice");
    }

    #[test]
    fn shared_bridge_halves_throughput() {
        let c = cfg(1);
        let mut topo = Topology::new(&c);
        let path = topo.path_via_nic(0, 0, Dir::In);
        // Saturate with many 64 KiB transfers; steady-state throughput
        // through the doubly-crossed bridge must be ~bw/2.
        let n = 2000u64;
        let bytes = 64 * 1024;
        let mut finish = 0;
        for _ in 0..n {
            finish = topo.transfer(0, bytes, &path);
        }
        let bw = n as f64 * bytes as f64 / (finish as f64 / 1e9);
        let expect = c.pcie.link_bw / 2.0;
        assert!(
            (bw - expect).abs() / expect < 0.05,
            "bw={:.2e} expect={:.2e}",
            bw,
            expect
        );
    }

    #[test]
    fn direct_path_full_bandwidth() {
        let c = cfg(1);
        let mut topo = Topology::new(&c);
        let path = topo.path_direct(0, Dir::In);
        let n = 2000u64;
        let bytes = 64 * 1024;
        let mut finish = 0;
        for _ in 0..n {
            finish = topo.transfer(0, bytes, &path);
        }
        let bw = n as f64 * bytes as f64 / (finish as f64 / 1e9);
        assert!(
            (bw - c.pcie.link_bw).abs() / c.pcie.link_bw < 0.05,
            "bw={bw:.2e}"
        );
    }

    #[test]
    fn two_nics_double_throughput() {
        let c = cfg(2);
        let mut topo = Topology::new(&c);
        let p0 = topo.path_via_nic(0, 0, Dir::In);
        let p1 = topo.path_via_nic(1, 0, Dir::In);
        let n = 2000u64;
        let bytes = 64 * 1024;
        let mut finish = 0;
        for i in 0..n {
            let p = if i % 2 == 0 { &p0 } else { &p1 };
            finish = finish.max(topo.transfer(0, bytes, p));
        }
        let bw = n as f64 * bytes as f64 / (finish as f64 / 1e9);
        // Two bridges at bw/2 each = bw total (mem + gpu.down can carry it).
        assert!(
            (bw - c.pcie.link_bw).abs() / c.pcie.link_bw < 0.08,
            "bw={bw:.2e}"
        );
    }

    #[test]
    fn contention_serializes() {
        let c = cfg(1);
        let mut topo = Topology::new(&c);
        let path = topo.path_direct(0, Dir::In);
        let t1 = topo.transfer(0, 1_000_000, &path);
        let t2 = topo.transfer(0, 1_000_000, &path);
        assert!(t2 > t1);
    }

    #[test]
    fn utilization_export() {
        let c = cfg(1);
        let mut topo = Topology::new(&c);
        let path = topo.path_direct(0, Dir::In);
        topo.transfer(0, 13_000_000, &path); // ~1 ms on the gpu link
        let mut m = crate::metrics::Metrics::new();
        m.finish_ns = 2_000_000;
        topo.export_utilization(&mut m);
        let u = m.link_utilization("gpu0.down");
        assert!((0.4..=0.6).contains(&u), "u={u}");
    }

    #[test]
    fn nvlink_channel_carries_aggregate_bandwidth() {
        let c = cfg(1);
        let mut topo = Topology::new(&c);
        let path = topo.path_nvlink(0, Dir::In);
        let nvl = topo.find_link("nvlink0.down").unwrap();
        assert!(path.contains(&nvl), "nvlink path uses its channel");
        let n = 2000u64;
        let bytes = 64 * 1024;
        let mut finish = 0;
        for _ in 0..n {
            finish = topo.transfer(0, bytes, &path);
        }
        let bw = n as f64 * bytes as f64 / (finish as f64 / 1e9);
        let expect = c.nvlink.num_links as f64 * c.nvlink.link_bw;
        assert!((bw - expect).abs() / expect < 0.05, "bw={bw:.2e}");
    }

    #[test]
    fn unshared_bridge_uses_separate_uplink() {
        let mut c = cfg(1);
        c.pcie.nic_bridge_shared = false;
        let topo = Topology::new(&c);
        let path = topo.path_via_nic(0, 0, Dir::In);
        let nic = topo.find_link("nic0").unwrap();
        let nic_up = topo.find_link("nic0.up").unwrap();
        assert!(path.contains(&nic) && path.contains(&nic_up));
    }
}
