//! Differential conformance: replay one trace under two backend/policy
//! configurations and report the first diverging event.
//!
//! This is the oracle behind `gpuvm trace diff` and
//! `rust/tests/conformance.rs`: identical configurations must replay a
//! trace with **zero divergence** (the DES is deterministic end to end),
//! and a policy/transport change shows exactly *where* behavior first
//! departs — the event index, not just drifted aggregates.

use super::replay::TraceWorkload;
use super::{capture_run, Trace, TraceEvent};
use crate::config::SystemConfig;
use anyhow::Result;

/// The first point where two event streams disagree. `a`/`b` are `None`
/// when that side's stream ended before the index (length mismatch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Divergence {
    /// Logical timestamp (stream index) of the first disagreement.
    pub index: usize,
    pub a: Option<TraceEvent>,
    pub b: Option<TraceEvent>,
}

/// Compare two streams; `ignore_timing` compares only the structural
/// fields (kind, gpu, page, aux), useful across transports whose `at`
/// values legitimately differ.
pub fn first_divergence(
    a: &[TraceEvent],
    b: &[TraceEvent],
    ignore_timing: bool,
) -> Option<Divergence> {
    let eq = |x: &TraceEvent, y: &TraceEvent| {
        if ignore_timing {
            (x.kind, x.gpu, x.page, x.aux) == (y.kind, y.gpu, y.page, y.aux)
        } else {
            x == y
        }
    };
    let n = a.len().min(b.len());
    for i in 0..n {
        if !eq(&a[i], &b[i]) {
            return Some(Divergence {
                index: i,
                a: Some(a[i]),
                b: Some(b[i]),
            });
        }
    }
    if a.len() != b.len() {
        return Some(Divergence {
            index: n,
            a: a.get(n).copied(),
            b: b.get(n).copied(),
        });
    }
    None
}

/// One side of a differential replay.
#[derive(Debug, Clone)]
pub struct DiffSide {
    pub backend: String,
    pub events: Vec<TraceEvent>,
    /// Canonical deterministic counters ([`crate::metrics::Metrics::fingerprint`]).
    pub fingerprint: Vec<(&'static str, u64)>,
}

/// Outcome of [`replay_diff`].
#[derive(Debug, Clone)]
pub struct DiffReport {
    pub a: DiffSide,
    pub b: DiffSide,
    pub divergence: Option<Divergence>,
}

impl DiffReport {
    pub fn identical(&self) -> bool {
        self.divergence.is_none()
    }

    /// Human-readable report (the `gpuvm trace diff` output).
    pub fn render(&self) -> String {
        let mut s = format!(
            "A: {} ({} events)\nB: {} ({} events)\n",
            self.a.backend,
            self.a.events.len(),
            self.b.backend,
            self.b.events.len()
        );
        let differing: Vec<String> = self
            .a
            .fingerprint
            .iter()
            .zip(&self.b.fingerprint)
            .filter(|((_, va), (_, vb))| va != vb)
            .map(|((k, va), (_, vb))| format!("  {k}: {va} vs {vb}"))
            .collect();
        if differing.is_empty() {
            s.push_str("metrics: identical\n");
        } else {
            s.push_str("metrics (differing):\n");
            s.push_str(&differing.join("\n"));
            s.push('\n');
        }
        match &self.divergence {
            None => s.push_str(&format!(
                "event streams identical ({} events, zero divergence)\n",
                self.a.events.len()
            )),
            Some(d) => {
                // A little common-prefix context helps place the split.
                let from = d.index.saturating_sub(3);
                for i in from..d.index {
                    s.push_str(&format!("  #{i} (both): {}\n", self.a.events[i].describe()));
                }
                s.push_str(&format!("first divergence at event #{}:\n", d.index));
                let side = |tag: &str, e: &Option<TraceEvent>| match e {
                    Some(e) => format!("  {tag}: {}\n", e.describe()),
                    None => format!("  {tag}: <stream ended>\n"),
                };
                s.push_str(&side("A", &d.a));
                s.push_str(&side("B", &d.b));
            }
        }
        s
    }
}

/// Replay `trace` once under (`cfg`, `backend`), capturing the resulting
/// stream and metrics fingerprint.
pub fn replay_once(trace: &Trace, cfg: &SystemConfig, backend: &str) -> Result<DiffSide> {
    let mut w = TraceWorkload::new(trace);
    let (events, truncated, r) = capture_run(cfg, backend, &mut w)?;
    anyhow::ensure!(
        !truncated,
        "replay capture truncated at {} events; raise trace.max_events",
        events.len()
    );
    Ok(DiffSide {
        backend: backend.to_string(),
        events,
        fingerprint: r.metrics.fingerprint(),
    })
}

/// Replay `trace` under two configurations and diff the streams.
pub fn replay_diff(
    trace: &Trace,
    cfg_a: &SystemConfig,
    backend_a: &str,
    cfg_b: &SystemConfig,
    backend_b: &str,
    ignore_timing: bool,
) -> Result<DiffReport> {
    let a = replay_once(trace, cfg_a, backend_a)?;
    let b = replay_once(trace, cfg_b, backend_b)?;
    let divergence = first_divergence(&a.events, &b.events, ignore_timing);
    Ok(DiffReport { a, b, divergence })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceEventKind;

    fn ev(at: u64, kind: TraceEventKind, page: u64) -> TraceEvent {
        TraceEvent {
            at,
            page,
            aux: 0,
            kind,
            gpu: 0,
        }
    }

    #[test]
    fn identical_streams_have_no_divergence() {
        let a = vec![ev(1, TraceEventKind::Fault, 0), ev(2, TraceEventKind::Fill, 0)];
        assert_eq!(first_divergence(&a, &a.clone(), false), None);
        assert_eq!(first_divergence(&[], &[], false), None);
    }

    #[test]
    fn first_structural_difference_is_reported() {
        let a = vec![ev(1, TraceEventKind::Fault, 0), ev(2, TraceEventKind::Fill, 0)];
        let b = vec![ev(1, TraceEventKind::Fault, 0), ev(2, TraceEventKind::Fill, 1)];
        let d = first_divergence(&a, &b, false).unwrap();
        assert_eq!(d.index, 1);
        assert_eq!(d.a.unwrap().page, 0);
        assert_eq!(d.b.unwrap().page, 1);
    }

    #[test]
    fn timing_only_differences_respect_the_flag() {
        let a = vec![ev(1, TraceEventKind::Fault, 0)];
        let b = vec![ev(99, TraceEventKind::Fault, 0)];
        assert!(first_divergence(&a, &b, false).is_some());
        assert_eq!(first_divergence(&a, &b, true), None);
    }

    #[test]
    fn length_mismatch_diverges_at_the_shorter_end() {
        let a = vec![ev(1, TraceEventKind::Fault, 0), ev(2, TraceEventKind::Fill, 0)];
        let b = vec![ev(1, TraceEventKind::Fault, 0)];
        let d = first_divergence(&a, &b, false).unwrap();
        assert_eq!(d.index, 1);
        assert!(d.a.is_some() && d.b.is_none());
    }
}
