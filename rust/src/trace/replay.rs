//! Replay: re-drive any backend from a recorded demand-fault stream.
//!
//! [`TraceWorkload`] is a normal [`Workload`], so `trace:PATH` specs run
//! everywhere specs run — `gpuvm run/sweep`, [`Session`] sweeps, benches.
//! Replay is deliberately *canonical* rather than concurrent: the
//! recorded leader-fault stream is already serialized in logical-
//! timestamp order, so one warp re-issues one page-sized access per
//! recorded fault. That makes replay deterministic by construction (no
//! warp interleaving of its own) — exactly what a conformance oracle
//! needs: two replays of the same trace under the same configuration
//! must produce bit-identical event streams.
//!
//! Regions are re-registered with the recorded sizes and read-mostly
//! flags, reproducing the capture-time global page numbering. Recorded
//! page ids address the *capture-time* page size; replay converts them
//! to byte ranges, so a trace stays meaningful when replayed under a
//! different `gpuvm.page_size` (the range is clamped to the region's
//! registered bytes).
//!
//! [`Session`]: crate::coordinator::Session

use super::{Trace, TraceEventKind};
use crate::gpu::kernel::{Access, Launch, WarpOp, Workload};
use crate::mem::{HostMemory, RegionId};

/// Capture-time layout of one region.
#[derive(Debug, Clone, Copy)]
struct RegionLayout {
    base_page: u64,
    num_pages: u64,
    len_bytes: u64,
    read_mostly: bool,
}

/// A workload that replays a recorded fault stream.
pub struct TraceWorkload {
    /// Capture-time page size (recorded page ids address this geometry).
    page_size: u64,
    layout: Vec<RegionLayout>,
    /// The demand-fault stream: (global page, write intent).
    faults: Vec<(u64, bool)>,
    /// Replay-time region ids, filled in `setup`.
    regions: Vec<RegionId>,
    launched: bool,
    step: usize,
}

impl TraceWorkload {
    pub fn new(trace: &Trace) -> Self {
        let ps = trace.meta.page_size.max(1);
        let mut base = 0u64;
        let layout: Vec<RegionLayout> = trace
            .meta
            .regions
            .iter()
            .map(|r| {
                let num_pages = r.len_bytes.div_ceil(ps).max(1);
                let l = RegionLayout {
                    base_page: base,
                    num_pages,
                    len_bytes: r.len_bytes,
                    read_mostly: r.read_mostly,
                };
                base += num_pages;
                l
            })
            .collect();
        let faults = trace
            .events
            .iter()
            .filter(|e| e.kind == TraceEventKind::Fault)
            .map(|e| (e.page, e.aux & 1 == 1))
            .collect();
        Self {
            page_size: ps,
            layout,
            faults,
            regions: Vec::new(),
            launched: false,
            step: 0,
        }
    }

    /// Replay scheduling hook for the determinism certifier
    /// ([`crate::analyze::perturb`]): re-drive the same recorded faults
    /// in a *permuted* issue order. `order[i]` names the recorded fault
    /// (index into the demand-fault stream) replayed at step `i`;
    /// `order` must be a permutation of `0..num_faults`.
    pub fn with_schedule(trace: &Trace, order: &[usize]) -> anyhow::Result<Self> {
        let base = Self::new(trace);
        anyhow::ensure!(
            order.len() == base.faults.len(),
            "schedule has {} entries for {} recorded faults",
            order.len(),
            base.faults.len()
        );
        let mut seen = vec![false; base.faults.len()];
        for &i in order {
            anyhow::ensure!(i < base.faults.len(), "schedule entry {i} out of range");
            anyhow::ensure!(!seen[i], "schedule repeats fault {i} (not a permutation)");
            seen[i] = true;
        }
        let faults = order.iter().map(|&i| base.faults[i]).collect();
        Ok(Self { faults, ..base })
    }

    /// Recorded demand faults to replay.
    pub fn num_faults(&self) -> usize {
        self.faults.len()
    }

    /// The demand-fault stream as recorded: (global page, write intent).
    pub fn fault_stream(&self) -> &[(u64, bool)] {
        &self.faults
    }

    /// Public [`Self::locate`]: map a recorded global page to its
    /// (region index, capture-time byte offset) — the certifier uses
    /// this for region-relative prefetch-group arithmetic.
    pub fn locate_page(&self, page: u64) -> Option<(usize, u64)> {
        self.locate(page)
    }

    /// Map a recorded global page to (region index, capture-time byte
    /// offset); None for pages outside the recorded layout (defensive —
    /// a well-formed trace never records one).
    fn locate(&self, page: u64) -> Option<(usize, u64)> {
        let idx = self
            .layout
            .partition_point(|l| l.base_page + l.num_pages <= page);
        let l = self.layout.get(idx)?;
        (page >= l.base_page).then(|| (idx, (page - l.base_page) * self.page_size))
    }
}

impl Workload for TraceWorkload {
    fn name(&self) -> &str {
        "trace"
    }

    fn setup(&mut self, hm: &mut HostMemory) {
        for (i, l) in self.layout.iter().enumerate() {
            let r = hm.register(&format!("t{i}"), l.len_bytes);
            if l.read_mostly {
                hm.advise_read_mostly(r);
            }
            self.regions.push(r);
        }
    }

    fn next_kernel(&mut self) -> Option<Launch> {
        if self.launched {
            return None;
        }
        self.launched = true;
        // One warp: the stream is replayed in logical-timestamp order.
        Some(Launch { warps: 1, tag: 0 })
    }

    fn next_op(&mut self, _warp: usize) -> WarpOp {
        loop {
            let Some(&(page, write)) = self.faults.get(self.step) else {
                return WarpOp::Done;
            };
            self.step += 1;
            let Some((idx, offset)) = self.locate(page) else {
                continue; // defensive: skip records outside the layout
            };
            let len_bytes = self.layout[idx].len_bytes;
            // Clamp to the region's registered bytes so replay under a
            // different page size cannot walk past its replay-time span.
            let (start, len) = if len_bytes == 0 {
                (0, 1)
            } else if offset >= len_bytes {
                (len_bytes - 1, 1)
            } else {
                (offset, (len_bytes - offset).min(self.page_size))
            };
            return WarpOp::Access(vec![Access::Seq {
                region: self.regions[idx],
                start,
                len,
                write,
            }]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{RegionMeta, TraceEvent, TraceMeta};

    fn trace_with(regions: Vec<RegionMeta>, faults: Vec<(u64, bool)>) -> Trace {
        let events = faults
            .iter()
            .enumerate()
            .map(|(i, &(page, write))| TraceEvent {
                at: i as u64,
                page,
                aux: write as u64,
                kind: TraceEventKind::Fault,
                gpu: 0,
            })
            .collect();
        Trace {
            meta: TraceMeta {
                backend: "gpuvm".into(),
                workload: "synthetic".into(),
                page_size: 4096,
                seed: 1,
                truncated: false,
                regions,
            },
            events,
        }
    }

    #[test]
    fn locate_maps_pages_to_regions_and_offsets() {
        // Region 0: 10000 B = 3 pages (0..3); region 1: 4096 B = 1 page (3).
        let t = trace_with(
            vec![
                RegionMeta {
                    len_bytes: 10_000,
                    read_mostly: false,
                },
                RegionMeta {
                    len_bytes: 4096,
                    read_mostly: true,
                },
            ],
            vec![],
        );
        let w = TraceWorkload::new(&t);
        assert_eq!(w.locate(0), Some((0, 0)));
        assert_eq!(w.locate(2), Some((0, 8192)));
        assert_eq!(w.locate(3), Some((1, 0)));
        assert_eq!(w.locate(4), None);
    }

    #[test]
    fn with_schedule_permutes_and_validates() {
        let t = trace_with(
            vec![RegionMeta {
                len_bytes: 1 << 20,
                read_mostly: false,
            }],
            vec![(0, false), (1, true), (2, false)],
        );
        let w = TraceWorkload::with_schedule(&t, &[2, 0, 1]).unwrap();
        assert_eq!(w.fault_stream(), &[(2, false), (0, false), (1, true)]);
        // The identity schedule reproduces the recorded stream.
        let id = TraceWorkload::with_schedule(&t, &[0, 1, 2]).unwrap();
        assert_eq!(id.fault_stream(), TraceWorkload::new(&t).fault_stream());
        // Wrong length, out-of-range, and repeats are rejected.
        assert!(TraceWorkload::with_schedule(&t, &[0, 1]).is_err());
        assert!(TraceWorkload::with_schedule(&t, &[0, 1, 3]).is_err());
        assert!(TraceWorkload::with_schedule(&t, &[0, 1, 1]).is_err());
    }

    #[test]
    fn replay_registers_recorded_regions_and_advice() {
        let t = trace_with(
            vec![
                RegionMeta {
                    len_bytes: 8192,
                    read_mostly: true,
                },
                RegionMeta {
                    len_bytes: 100,
                    read_mostly: false,
                },
            ],
            vec![(0, false)],
        );
        let mut w = TraceWorkload::new(&t);
        let mut hm = HostMemory::new(4096);
        w.setup(&mut hm);
        assert_eq!(hm.regions().len(), 2);
        assert!(hm.regions()[0].read_mostly);
        assert!(!hm.regions()[1].read_mostly);
        assert_eq!(hm.regions()[1].len_bytes, 100);
    }

    #[test]
    fn ops_replay_the_fault_stream_in_order_with_clamped_tails() {
        let t = trace_with(
            vec![RegionMeta {
                len_bytes: 10_000,
                read_mostly: false,
            }],
            vec![(0, false), (2, true), (99, false), (1, false)],
        );
        let mut w = TraceWorkload::new(&t);
        assert_eq!(w.num_faults(), 4);
        let mut hm = HostMemory::new(4096);
        w.setup(&mut hm);
        assert!(w.next_kernel().is_some());
        assert!(w.next_kernel().is_none());
        let expect = [
            (0u64, 4096u64, false),
            // Page 2 is the region tail: 10000 - 8192 = 1808 bytes.
            (8192, 1808, true),
            // Page 99 is outside the layout → skipped.
            (4096, 4096, false),
        ];
        for (start, len, write) in expect {
            match w.next_op(0) {
                WarpOp::Access(a) => match &a[0] {
                    Access::Seq {
                        start: s,
                        len: l,
                        write: wr,
                        ..
                    } => {
                        assert_eq!((*s, *l, *wr), (start, len, write));
                    }
                    other => panic!("unexpected access {other:?}"),
                },
                other => panic!("unexpected op {other:?}"),
            }
        }
        assert!(matches!(w.next_op(0), WarpOp::Done));
    }
}
