//! Deterministic fault-trace capture / replay.
//!
//! Every end-of-run number this reproduction reports is an *aggregate*;
//! nothing in the seed pinned the **event stream** itself, so a refactor
//! could silently reorder faults or drop migrations while every CSV
//! column still looked plausible. This subsystem closes that hole with
//! three parts:
//!
//! - **Capture** — a [`TraceSink`] observer threaded through the two
//!   paged memory systems ([`crate::gpuvm`], [`crate::uvm`]) records the
//!   canonical event stream (fault, fill, speculative fill, promote,
//!   evict clean/dirty/forced, WR post/completion) with logical
//!   timestamps. [`capture`] runs any spec under any paged backend and
//!   returns a [`Trace`]; [`Trace::save`]/[`Trace::load`] give it a
//!   compact versioned binary form (`format`), [`Trace::to_jsonl`] a
//!   JSON-lines debug form.
//! - **Replay** — `trace:PATH` is a first-class workload spec
//!   ([`crate::apps::WorkloadSpec`]): [`TraceWorkload`] re-drives any
//!   backend from a recorded demand-fault stream, so captured runs slot
//!   into [`crate::coordinator::Session`] sweeps and benches like any
//!   other app.
//! - **Conformance** — [`replay_diff`] replays one trace under two
//!   backend/policy configurations and reports the *first diverging
//!   event* ([`diff`]); golden traces under `rust/tests/golden/` pin the
//!   default-config streams of `gpuvm` and `uvm` bit for bit
//!   ([`golden_check`], `gpuvm trace golden`).
//!
//! ## Event vocabulary
//!
//! An event's *logical timestamp* is its index in the stream (events are
//! recorded in execution order; ties on the simulated clock keep their
//! execution order). `at` carries the simulated time in ns. Per-kind
//! payload:
//!
//! | kind            | `page`                         | `aux`                          |
//! |-----------------|--------------------------------|--------------------------------|
//! | `fault`         | faulting page (UVM: group head)| bit 0 = write intent           |
//! | `fill`          | page made resident             | bytes transferred              |
//! | `spec-fill`     | speculative fill (no waiter)   | bytes transferred              |
//! | `promote`       | first demand touch of a        | 0                              |
//! |                 | speculative page/group         |                                |
//! | `evict-clean`   | page/group head evicted        | 0                              |
//! | `evict-dirty`   | page/group head evicted        | bytes written back             |
//! | `evict-forced`  | UVM forced unmap (live refs)   | bytes written back (0 if clean)|
//! | `wr-post`       | page the WR moves              | `wr_id << 1 \| (dir == out)`   |
//! | `wr-complete`   | completion queue id            | `wr_id << 1`                   |
//!
//! UVM records a transfer's `wr-complete` at doorbell time (the driver
//! path learns its completion synchronously from the engine, so the
//! record carries a *future* `at` — the stream is execution-ordered,
//! not `at`-sorted); GPUVM records it when the CQ entry is polled. Both
//! are deterministic, which is all conformance needs. The completion's
//! `page` field names the completion queue (UVM's serialized driver
//! always uses copy queue 0), giving the happens-before analyzer
//! ([`crate::analyze::hb`]) one clock lane per queue.
//!
//! The per-kind payload table above is *enforced*, not just documented:
//! the protocol analyzer ([`crate::analyze`]) mechanizes it as
//! [`crate::analyze::protocol::payload_error`] and replays any captured
//! stream through the page-lifecycle state machine (`gpuvm analyze
//! <trace|golden|run>`), so a capture-path regression that emits a
//! malformed or out-of-order event fails the lint, not just the golden
//! byte-compare.

pub mod diff;
pub mod format;
pub mod replay;

pub use diff::{first_divergence, replay_diff, replay_once, DiffReport, DiffSide, Divergence};
pub use replay::TraceWorkload;

use crate::apps::{BuildOpts, WorkloadSpec};
use crate::config::SystemConfig;
use crate::coordinator::backend;
use crate::gpu::exec::{self, RunResult};
use crate::gpu::kernel::Workload;
use crate::sim::SimTime;
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::path::Path;
use std::rc::Rc;

/// What happened (see the module table for per-kind payloads).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum TraceEventKind {
    /// Leader-level demand fault (post-coalescing).
    Fault = 0,
    /// A demanded page/group became resident.
    Fill = 1,
    /// A speculative (prefetch-issued, no demand waiter) fill completed.
    SpecFill = 2,
    /// First demand touch of a page/group that arrived speculatively.
    Promote = 3,
    /// Eviction of a clean page/group.
    EvictClean = 4,
    /// Eviction of a dirty page/group (bytes written back in `aux`).
    EvictDirty = 5,
    /// UVM only: eviction forced through a live reference count.
    EvictForced = 6,
    /// A work request was posted to the transport.
    WrPost = 7,
    /// A work request's completion was observed.
    WrComplete = 8,
}

impl TraceEventKind {
    /// Every kind, in wire order.
    pub const ALL: [TraceEventKind; 9] = [
        TraceEventKind::Fault,
        TraceEventKind::Fill,
        TraceEventKind::SpecFill,
        TraceEventKind::Promote,
        TraceEventKind::EvictClean,
        TraceEventKind::EvictDirty,
        TraceEventKind::EvictForced,
        TraceEventKind::WrPost,
        TraceEventKind::WrComplete,
    ];

    /// Stable wire/debug name.
    pub fn name(self) -> &'static str {
        match self {
            TraceEventKind::Fault => "fault",
            TraceEventKind::Fill => "fill",
            TraceEventKind::SpecFill => "spec-fill",
            TraceEventKind::Promote => "promote",
            TraceEventKind::EvictClean => "evict-clean",
            TraceEventKind::EvictDirty => "evict-dirty",
            TraceEventKind::EvictForced => "evict-forced",
            TraceEventKind::WrPost => "wr-post",
            TraceEventKind::WrComplete => "wr-complete",
        }
    }

    /// Decode a wire byte; unknown values are a format error.
    pub fn from_u8(b: u8) -> Result<Self> {
        Self::ALL
            .get(b as usize)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown trace event kind {b}"))
    }
}

/// One recorded event. The stream index is the logical timestamp.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Simulated time, ns.
    pub at: SimTime,
    /// Global page id (see the module table; 0 where not applicable).
    pub page: u64,
    /// Kind-specific payload (see the module table).
    pub aux: u64,
    pub kind: TraceEventKind,
    pub gpu: u8,
}

impl TraceEvent {
    /// One-line human form (`diff` output, error messages).
    pub fn describe(&self) -> String {
        format!(
            "{} at={}ns gpu={} page={} aux={}",
            self.kind.name(),
            self.at,
            self.gpu,
            self.page,
            self.aux
        )
    }
}

/// Observer the paged memory systems feed
/// ([`crate::memsys::MemorySystem::set_trace_sink`]).
pub trait TraceSink {
    fn record(&mut self, ev: TraceEvent);
}

/// The handle a memory system holds: shared, single-threaded (runs are
/// single-threaded; sweeps build one system per worker thread).
pub type SharedSink = Rc<RefCell<dyn TraceSink>>;

/// Record `ev` into an optional sink. Free function on purpose: call
/// sites inside the memory systems hold field-level `&mut` borrows, and
/// `emit(&self.sink, ...)` borrows only the sink field.
#[inline]
pub fn emit(
    sink: &Option<SharedSink>,
    at: SimTime,
    gpu: usize,
    kind: TraceEventKind,
    page: u64,
    aux: u64,
) {
    if let Some(s) = sink {
        s.borrow_mut().record(TraceEvent {
            at,
            page,
            aux,
            kind,
            gpu: gpu as u8,
        });
    }
}

/// In-memory sink with an optional event cap (`trace.max_events`):
/// recording past the cap drops events and sets `truncated` instead of
/// growing without bound on huge runs.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    pub events: Vec<TraceEvent>,
    cap: u64,
    pub truncated: bool,
}

impl Recorder {
    pub fn new() -> Self {
        Self::with_cap(0)
    }

    /// `cap = 0` means unlimited.
    pub fn with_cap(cap: u64) -> Self {
        Self {
            events: Vec::new(),
            cap,
            truncated: false,
        }
    }
}

impl TraceSink for Recorder {
    fn record(&mut self, ev: TraceEvent) {
        if self.cap != 0 && self.events.len() as u64 >= self.cap {
            self.truncated = true;
            return;
        }
        self.events.push(ev);
        crate::obs::hostprof::count("trace/events_recorded", 1);
    }
}

/// One registered host region, as the capture-time run laid it out.
/// Replay re-registers regions in order, reproducing the global page
/// numbering.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegionMeta {
    pub len_bytes: u64,
    pub read_mostly: bool,
}

/// Everything needed to interpret and replay an event stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceMeta {
    /// Backend that produced the stream (`gpuvm`, `uvm`, ...).
    pub backend: String,
    /// Workload spec (or label) the capture ran.
    pub workload: String,
    /// Capture-time page size — recorded page ids address this geometry.
    pub page_size: u64,
    /// Capture-time RNG seed.
    pub seed: u64,
    /// The recorder hit `trace.max_events` and dropped the tail.
    pub truncated: bool,
    /// Host regions in registration order.
    pub regions: Vec<RegionMeta>,
}

/// A captured run: metadata + the canonical event stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trace {
    pub meta: TraceMeta,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Number of leader demand faults (the replayable stream).
    pub fn num_faults(&self) -> usize {
        self.count_kind(TraceEventKind::Fault)
    }

    /// Number of events of one kind (the analyzer's metrics bridge,
    /// [`crate::analyze::lint::metrics_mismatches`], compares these
    /// against [`crate::metrics::Metrics::trace_expectations`]).
    pub fn count_kind(&self, kind: TraceEventKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }
}

/// Run `workload` under the named *paged* backend with a recorder
/// attached; returns the raw event stream (plus the truncation flag) and
/// the run result. Bulk backends have no paged event stream and are
/// rejected.
pub fn capture_run(
    cfg: &SystemConfig,
    backend_name: &str,
    workload: &mut dyn Workload,
) -> Result<(Vec<TraceEvent>, bool, RunResult)> {
    let (events, truncated, r, _) = capture_run_observed(cfg, backend_name, workload)?;
    Ok((events, truncated, r))
}

/// [`capture_run`] plus the interval sampler: when `cfg.obs.enabled`, a
/// [`crate::obs::Sampler`] is attached alongside the recorder and
/// returned with its samples (empty, never ticked, when obs is off).
/// The `gpuvm profile` verb and the obs tests use this; plain capture
/// callers keep the narrower [`capture_run`] signature.
pub fn capture_run_observed(
    cfg: &SystemConfig,
    backend_name: &str,
    workload: &mut dyn Workload,
) -> Result<(Vec<TraceEvent>, bool, RunResult, crate::obs::Sampler)> {
    let b = backend::lookup(backend_name)?;
    let mut mem = b.build_memsys(cfg).ok_or_else(|| {
        anyhow::anyhow!(
            "backend '{backend_name}' is a bulk engine; trace capture needs \
             a paged memory system (gpuvm|uvm|uvm-memadvise|ideal)"
        )
    })?;
    let rec = Rc::new(RefCell::new(Recorder::with_cap(cfg.trace.max_events)));
    mem.set_trace_sink(rec.clone());
    let obs = crate::obs::Sampler::shared(&cfg.obs);
    if cfg.obs.enabled {
        mem.set_obs(obs.clone());
    }
    let r = exec::run(cfg, workload, mem.as_mut())?;
    drop(mem);
    let rec = match Rc::try_unwrap(rec) {
        Ok(cell) => cell.into_inner(),
        Err(rc) => rc.borrow().clone(),
    };
    let obs = match Rc::try_unwrap(obs) {
        Ok(cell) => cell.into_inner(),
        Err(rc) => rc.borrow().clone(),
    };
    Ok((rec.events, rec.truncated, r, obs))
}

/// Capture an already-constructed workload (`label` becomes the trace's
/// workload field). The spec-based [`capture`] wraps this.
pub fn capture_workload(
    cfg: &SystemConfig,
    backend_name: &str,
    workload: &mut dyn Workload,
    label: &str,
) -> Result<(Trace, RunResult)> {
    let (t, r, _) = capture_workload_observed(cfg, backend_name, workload, label)?;
    Ok((t, r))
}

/// [`capture_workload`] plus the interval sampler (see
/// [`capture_run_observed`]).
pub fn capture_workload_observed(
    cfg: &SystemConfig,
    backend_name: &str,
    workload: &mut dyn Workload,
    label: &str,
) -> Result<(Trace, RunResult, crate::obs::Sampler)> {
    let (events, truncated, r, obs) = capture_run_observed(cfg, backend_name, workload)?;
    let meta = TraceMeta {
        backend: backend_name.to_string(),
        workload: label.to_string(),
        page_size: cfg.gpuvm.page_size,
        seed: cfg.seed,
        truncated,
        regions: r
            .hm
            .regions()
            .iter()
            .map(|rg| RegionMeta {
                len_bytes: rg.len_bytes,
                read_mostly: rg.read_mostly,
            })
            .collect(),
    };
    Ok((Trace { meta, events }, r, obs))
}

/// Capture `spec` under `backend_name` on `cfg`'s testbed. Advising
/// backends (`uvm-memadvise`) apply their read-mostly hint exactly as in
/// a normal run, and the advice is recorded in the trace's region table
/// so replay reproduces it.
pub fn capture(
    cfg: &SystemConfig,
    spec: &WorkloadSpec,
    opts: &BuildOpts,
    backend_name: &str,
) -> Result<(Trace, RunResult)> {
    let (t, r, _) = capture_observed(cfg, spec, opts, backend_name)?;
    Ok((t, r))
}

/// [`capture`] plus the interval sampler (see [`capture_run_observed`]);
/// the `gpuvm profile run` verb's capture path.
pub fn capture_observed(
    cfg: &SystemConfig,
    spec: &WorkloadSpec,
    opts: &BuildOpts,
    backend_name: &str,
) -> Result<(Trace, RunResult, crate::obs::Sampler)> {
    let b = backend::lookup(backend_name)?;
    let mut o = opts.clone();
    o.advise = o.advise || b.advise();
    let mut w = spec.build(&o)?;
    capture_workload_observed(cfg, backend_name, w.as_mut(), spec.raw())
}

// ---- golden traces ---------------------------------------------------

/// The pinned golden scenario: a small machine (fast enough for every
/// `cargo test`) oversubscribed enough that both paged systems evict —
/// so the goldens pin fault, fill, evict *and* WR behavior. Everything
/// else is `SystemConfig::default()`, i.e. the default policies
/// (fifo-refcount / tree-lru, none / fixed prefetch, rdma / pcie-dma).
pub fn golden_config() -> SystemConfig {
    let mut c = SystemConfig::default();
    c.gpu.sms = 4;
    c.gpu.warps_per_sm = 2;
    c.gpu.mem_bytes = 2 << 20; // 512 gpuvm frames / 32 uvm groups
    c.gpuvm.page_size = 4096;
    c.gpuvm.num_qps = 16;
    c
}

/// The golden workload: 3 MiB of vector add over 2 MiB of GPU memory.
pub const GOLDEN_WORKLOAD: &str = "va@256k";

/// Backends with committed golden streams.
pub const GOLDEN_BACKENDS: [&str; 2] = ["gpuvm", "uvm"];

/// Capture the golden scenario for `backend`.
pub fn golden_capture(backend_name: &str) -> Result<Trace> {
    let cfg = golden_config();
    let spec = WorkloadSpec::parse(GOLDEN_WORKLOAD)?;
    let opts = BuildOpts::for_cfg(&cfg);
    Ok(capture(&cfg, &spec, &opts, backend_name)?.0)
}

/// Outcome of a golden check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GoldenStatus {
    /// The golden file was missing and has been created — commit it.
    Created,
    /// The captured stream matches the committed golden bit for bit.
    Verified,
}

/// Verify (or bootstrap) the golden trace for `backend` in `dir`.
///
/// - File present and identical → [`GoldenStatus::Verified`].
/// - File present but different → error naming the first diverging
///   event; the fresh capture is written next to the golden as
///   `<name>.trace.new` plus a `<name>.divergence.jsonl` report (CI
///   uploads both as artifacts).
/// - File missing and `write_missing` → the capture is written and
///   [`GoldenStatus::Created`] returned (commit the file); without
///   `write_missing`, missing is an error.
pub fn golden_check(dir: &Path, backend_name: &str, write_missing: bool) -> Result<GoldenStatus> {
    let path = dir.join(format!("{backend_name}_default.trace"));
    let fresh = golden_capture(backend_name)?;
    if !path.exists() {
        anyhow::ensure!(
            write_missing,
            "golden trace {} missing (regenerate: gpuvm trace golden --dir {})",
            path.display(),
            dir.display()
        );
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        fresh.save(&path)?;
        return Ok(GoldenStatus::Created);
    }
    let committed = Trace::load(&path)?;
    if committed == fresh {
        return Ok(GoldenStatus::Verified);
    }
    // Divergence: leave the evidence on disk for CI artifacts.
    let div = first_divergence(&committed.events, &fresh.events, false);
    let new_path = dir.join(format!("{backend_name}_default.trace.new"));
    fresh.save(&new_path)?;
    let mut report = String::new();
    let (idx, a, b) = match &div {
        Some(d) => (d.index, d.a, d.b),
        // Streams equal but meta differs (e.g. config drift).
        None => (committed.events.len(), None, None),
    };
    report.push_str(&format!(
        "{{\"golden\":\"{}\",\"divergence_index\":{},\"committed\":\"{}\",\"fresh\":\"{}\"}}\n",
        path.display(),
        idx,
        a.map_or_else(|| "<end>".into(), |e| e.describe()),
        b.map_or_else(|| "<end>".into(), |e| e.describe()),
    ));
    report.push_str(&fresh.to_jsonl());
    let div_path = dir.join(format!("{backend_name}_default.divergence.jsonl"));
    std::fs::write(&div_path, report)
        .with_context(|| format!("writing {}", div_path.display()))?;
    anyhow::bail!(
        "golden trace mismatch for '{backend_name}': first divergence at event {idx} \
         (committed: {}, fresh: {}); fresh capture at {}, report at {}. If the \
         change is intended, replace the golden and commit it.",
        a.map_or_else(|| "<stream ended>".into(), |e| e.describe()),
        b.map_or_else(|| "<stream ended>".into(), |e| e.describe()),
        new_path.display(),
        div_path.display()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_round_trip_the_wire_byte() {
        for (i, k) in TraceEventKind::ALL.iter().enumerate() {
            assert_eq!(TraceEventKind::from_u8(i as u8).unwrap(), *k);
            assert!(!k.name().is_empty());
        }
        assert!(TraceEventKind::from_u8(9).is_err());
    }

    #[test]
    fn recorder_cap_truncates_instead_of_growing() {
        let mut r = Recorder::with_cap(2);
        let ev = TraceEvent {
            at: 1,
            page: 2,
            aux: 3,
            kind: TraceEventKind::Fault,
            gpu: 0,
        };
        for _ in 0..5 {
            r.record(ev);
        }
        assert_eq!(r.events.len(), 2);
        assert!(r.truncated);
        let mut unlimited = Recorder::new();
        for _ in 0..5 {
            unlimited.record(ev);
        }
        assert_eq!(unlimited.events.len(), 5);
        assert!(!unlimited.truncated);
    }

    #[test]
    fn emit_is_a_noop_without_a_sink() {
        // Must not panic; the hot path gates on the Option.
        emit(&None, 1, 0, TraceEventKind::Fill, 0, 0);
        let rec: Rc<RefCell<Recorder>> = Rc::new(RefCell::new(Recorder::new()));
        let sink: Option<SharedSink> = Some(rec.clone());
        emit(&sink, 7, 1, TraceEventKind::Fault, 42, 1);
        assert_eq!(rec.borrow().events.len(), 1);
        assert_eq!(rec.borrow().events[0].page, 42);
        assert_eq!(rec.borrow().events[0].gpu, 1);
    }
}
