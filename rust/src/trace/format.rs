//! Trace serialization: a compact versioned binary form plus a
//! JSON-lines debug form.
//!
//! Binary layout (all integers little-endian):
//!
//! ```text
//! magic      4  b"GVMT"
//! version    2  format version (currently 1)
//! flags      2  bit 0 = truncated (recorder hit trace.max_events)
//! page_size  8
//! seed       8
//! backend    2 + n   length-prefixed UTF-8
//! workload   2 + n   length-prefixed UTF-8
//! regions    4 + 9·n count, then per region: len_bytes u64, read_mostly u8
//! events     8 + 26·n count, then per event:
//!            at u64, page u64, aux u64, kind u8, gpu u8
//! ```
//!
//! Trailing bytes, bad magic, unknown versions/kinds, and short buffers
//! are all hard errors — a golden comparison must never "mostly parse".

use super::{RegionMeta, Trace, TraceEvent, TraceEventKind, TraceMeta};
use crate::util::json::json_string as jstr;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// File magic.
pub const MAGIC: [u8; 4] = *b"GVMT";
/// Current format version.
pub const VERSION: u16 = 1;
/// Bytes per serialized event record.
pub const EVENT_BYTES: usize = 26;

const FLAG_TRUNCATED: u16 = 1;

fn put_str(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    let len = b.len().min(u16::MAX as usize);
    out.extend_from_slice(&(len as u16).to_le_bytes());
    out.extend_from_slice(&b[..len]);
}

/// Bounds-checked little-endian reader.
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "trace file truncated: wanted {n} bytes at offset {}, have {}",
                    self.pos,
                    self.b.len()
                )
            })?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).context("trace string not UTF-8")
    }
}

impl Trace {
    /// Serialize to the versioned binary form.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.events.len() * EVENT_BYTES);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        let flags: u16 = if self.meta.truncated { FLAG_TRUNCATED } else { 0 };
        out.extend_from_slice(&flags.to_le_bytes());
        out.extend_from_slice(&self.meta.page_size.to_le_bytes());
        out.extend_from_slice(&self.meta.seed.to_le_bytes());
        put_str(&mut out, &self.meta.backend);
        put_str(&mut out, &self.meta.workload);
        out.extend_from_slice(&(self.meta.regions.len() as u32).to_le_bytes());
        for r in &self.meta.regions {
            out.extend_from_slice(&r.len_bytes.to_le_bytes());
            out.push(r.read_mostly as u8);
        }
        out.extend_from_slice(&(self.events.len() as u64).to_le_bytes());
        for e in &self.events {
            out.extend_from_slice(&e.at.to_le_bytes());
            out.extend_from_slice(&e.page.to_le_bytes());
            out.extend_from_slice(&e.aux.to_le_bytes());
            out.push(e.kind as u8);
            out.push(e.gpu);
        }
        out
    }

    /// Parse the binary form; strict about magic/version/length.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = Reader { b: bytes, pos: 0 };
        let magic = r.take(4)?;
        if magic != MAGIC {
            bail!("not a gpuvm trace (bad magic {magic:02x?}, want {MAGIC:02x?})");
        }
        let version = r.u16()?;
        if version != VERSION {
            bail!("trace format version {version} unsupported (this build reads {VERSION})");
        }
        let flags = r.u16()?;
        let page_size = r.u64()?;
        let seed = r.u64()?;
        let backend = r.str()?;
        let workload = r.str()?;
        let num_regions = r.u32()? as usize;
        let mut regions = Vec::with_capacity(num_regions.min(1 << 16));
        for _ in 0..num_regions {
            let len_bytes = r.u64()?;
            let read_mostly = r.u8()? != 0;
            regions.push(RegionMeta {
                len_bytes,
                read_mostly,
            });
        }
        let num_events = r.u64()? as usize;
        // Validate the claimed count against the remaining bytes before
        // reserving memory for it (checked: a corrupt count must not
        // wrap past the comparison and panic in with_capacity).
        let remaining = bytes.len() - r.pos;
        if num_events.checked_mul(EVENT_BYTES) != Some(remaining) {
            bail!(
                "trace body length mismatch: header claims {num_events} events, \
                 file has {remaining} bytes for them"
            );
        }
        let mut events = Vec::with_capacity(num_events);
        for _ in 0..num_events {
            let at = r.u64()?;
            let page = r.u64()?;
            let aux = r.u64()?;
            let kind = TraceEventKind::from_u8(r.u8()?)?;
            let gpu = r.u8()?;
            events.push(TraceEvent {
                at,
                page,
                aux,
                kind,
                gpu,
            });
        }
        Ok(Trace {
            meta: TraceMeta {
                backend,
                workload,
                page_size,
                seed,
                truncated: flags & FLAG_TRUNCATED != 0,
                regions,
            },
            events,
        })
    }

    /// Write the binary form to `path`.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing trace {}", path.display()))
    }

    /// Read the binary form from `path`.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing trace {}", path.display()))
    }

    /// JSON-lines debug form: one header object, then one object per
    /// event (`i` is the logical timestamp).
    pub fn to_jsonl(&self) -> String {
        let regions: Vec<String> = self
            .meta
            .regions
            .iter()
            .map(|r| {
                format!(
                    "{{\"len_bytes\":{},\"read_mostly\":{}}}",
                    r.len_bytes, r.read_mostly
                )
            })
            .collect();
        let mut s = format!(
            "{{\"format\":\"gpuvm-trace\",\"version\":{},\"backend\":{},\"workload\":{},\
             \"page_size\":{},\"seed\":{},\"truncated\":{},\"regions\":[{}],\"events\":{}}}\n",
            VERSION,
            jstr(&self.meta.backend),
            jstr(&self.meta.workload),
            self.meta.page_size,
            self.meta.seed,
            self.meta.truncated,
            regions.join(","),
            self.events.len()
        );
        for (i, e) in self.events.iter().enumerate() {
            s.push_str(&format!(
                "{{\"i\":{i},\"at\":{},\"kind\":\"{}\",\"gpu\":{},\"page\":{},\"aux\":{}}}\n",
                e.at,
                e.kind.name(),
                e.gpu,
                e.page,
                e.aux
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            meta: TraceMeta {
                backend: "gpuvm".into(),
                workload: "va@64k".into(),
                page_size: 4096,
                seed: 0x5EED,
                truncated: false,
                regions: vec![
                    RegionMeta {
                        len_bytes: 262144,
                        read_mostly: true,
                    },
                    RegionMeta {
                        len_bytes: 100,
                        read_mostly: false,
                    },
                ],
            },
            events: vec![
                TraceEvent {
                    at: 60,
                    page: 0,
                    aux: 1,
                    kind: TraceEventKind::Fault,
                    gpu: 0,
                },
                TraceEvent {
                    at: 23_000,
                    page: 0,
                    aux: 4096,
                    kind: TraceEventKind::Fill,
                    gpu: 0,
                },
                TraceEvent {
                    at: 23_100,
                    page: 0,
                    aux: 7,
                    kind: TraceEventKind::WrComplete,
                    gpu: 1,
                },
            ],
        }
    }

    #[test]
    fn binary_round_trip_is_exact() {
        let t = sample();
        let b = t.to_bytes();
        let back = Trace::from_bytes(&b).unwrap();
        assert_eq!(t, back);
        // Bit-for-bit: re-serialization is byte-identical.
        assert_eq!(b, back.to_bytes());
    }

    #[test]
    fn truncated_flag_survives() {
        let mut t = sample();
        t.meta.truncated = true;
        let back = Trace::from_bytes(&t.to_bytes()).unwrap();
        assert!(back.meta.truncated);
    }

    #[test]
    fn bad_inputs_are_hard_errors() {
        let t = sample();
        let good = t.to_bytes();
        assert!(Trace::from_bytes(b"nope").is_err());
        // Wrong magic.
        let mut b = good.clone();
        b[0] = b'X';
        assert!(Trace::from_bytes(&b).unwrap_err().to_string().contains("magic"));
        // Future version.
        let mut b = good.clone();
        b[4] = 99;
        assert!(Trace::from_bytes(&b).unwrap_err().to_string().contains("version"));
        // Truncated body.
        let b = &good[..good.len() - 1];
        assert!(Trace::from_bytes(b).is_err());
        // Trailing garbage.
        let mut b = good.clone();
        b.push(0);
        assert!(Trace::from_bytes(&b).is_err());
        // Unknown event kind.
        let mut b = good.clone();
        let kind_off = good.len() - 2; // last event's kind byte
        b[kind_off] = 42;
        assert!(Trace::from_bytes(&b).unwrap_err().to_string().contains("kind"));
        // Absurd event count must error, not wrap/abort in with_capacity.
        let mut b = good.clone();
        let count_off = good.len() - 3 * EVENT_BYTES - 8;
        b[count_off..count_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = Trace::from_bytes(&b).unwrap_err().to_string();
        assert!(err.contains("length mismatch"), "{err}");
    }

    #[test]
    fn jsonl_has_header_plus_one_line_per_event() {
        let t = sample();
        let j = t.to_jsonl();
        let lines: Vec<&str> = j.lines().collect();
        assert_eq!(lines.len(), 1 + t.events.len());
        assert!(lines[0].contains("\"format\":\"gpuvm-trace\""));
        assert!(lines[0].contains("\"read_mostly\":true"));
        assert!(lines[1].contains("\"kind\":\"fault\""));
        assert!(lines[2].contains("\"kind\":\"fill\""));
    }

    #[test]
    fn save_load_round_trips_on_disk() {
        let t = sample();
        let path = std::env::temp_dir().join(format!(
            "gpuvm-trace-fmt-{}.trace",
            std::process::id()
        ));
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(t, back);
        let err = Trace::load("/nonexistent/definitely.trace").unwrap_err();
        assert!(format!("{err:#}").contains("definitely.trace"));
    }
}
