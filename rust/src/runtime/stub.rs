//! Offline stand-in for the PJRT runtime (built without `--features
//! xla`). Loading always fails with an actionable message; the methods
//! that need a loaded client are unreachable because a stub `Runtime`
//! can never be constructed. This keeps the coordinator's compute path,
//! the CLI's `e2e` command, and the PJRT tests compiling — they all
//! handle the load error gracefully — without the `xla` crate.

use super::tensor::{Tensor, TensorSpec};
use anyhow::Result;
use std::path::Path;

/// One compiled executable plus its manifest signature.
pub struct Artifact {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

enum Never {}

/// The stub runtime: uninhabited, so every method is trivially total.
pub struct Runtime {
    _never: Never,
}

impl Runtime {
    /// Standard location: `<repo>/artifacts` (built by `make artifacts`).
    pub fn load_default() -> Result<Self> {
        Self::load_dir("artifacts")
    }

    pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<Self> {
        anyhow::bail!(
            "PJRT runtime unavailable: this binary was built without the `xla` \
             feature (artifacts dir: {}); rebuild with `cargo build --features xla` \
             on a machine with the vendored xla crate",
            dir.as_ref().display()
        )
    }

    pub fn names(&self) -> Vec<&str> {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn artifact(&self, _name: &str) -> Result<&Artifact> {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn dir(&self) -> &Path {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn platform(&self) -> String {
        unreachable!("stub Runtime cannot be constructed")
    }

    pub fn execute(&self, _name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        unreachable!("stub Runtime cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loading_reports_the_missing_feature() {
        let err = Runtime::load_default().unwrap_err().to_string();
        assert!(err.contains("xla"), "{err}");
    }
}
