//! PJRT runtime: load the AOT artifacts (HLO text emitted by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//! Only compiled with `--features xla`; offline builds get the stub in
//! `stub.rs` with the same API surface.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). Graphs are lowered
//! with `return_tuple=True`, so outputs are unwrapped with `to_tuple()`.

use super::tensor::{Tensor, TensorSpec};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        Tensor::F32(v, _) => xla::Literal::vec1(v),
        Tensor::I32(v, _) => xla::Literal::vec1(v),
    };
    Ok(lit.reshape(&dims)?)
}

fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
    Ok(match spec.dtype.as_str() {
        "float32" => Tensor::F32(lit.to_vec::<f32>()?, spec.shape.clone()),
        "int32" => Tensor::I32(lit.to_vec::<i32>()?, spec.shape.clone()),
        other => bail!("unsupported artifact dtype {other}"),
    })
}

/// One compiled executable plus its manifest signature.
pub struct Artifact {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    exe: xla::PjRtLoadedExecutable,
}

/// The runtime: a PJRT CPU client and the compiled artifact table.
/// Python is done by now — this is the only compute engine on the
/// request path.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: HashMap<String, Artifact>,
    dir: PathBuf,
}

impl Runtime {
    /// Standard location: `<repo>/artifacts` (built by `make artifacts`).
    pub fn load_default() -> Result<Self> {
        Self::load_dir("artifacts")
    }

    pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest.display()
            )
        })?;
        let client = xla::PjRtClient::cpu()?;
        let mut artifacts = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let art = Self::load_line(&client, &dir, line)
                .with_context(|| format!("loading artifact '{line}'"))?;
            artifacts.insert(art.name.clone(), art);
        }
        ensure!(!artifacts.is_empty(), "empty artifact manifest");
        Ok(Self {
            client,
            artifacts,
            dir,
        })
    }

    fn load_line(client: &xla::PjRtClient, dir: &Path, line: &str) -> Result<Artifact> {
        // "<name> <file> <in;in;..> -> <out;out;..>"
        let mut parts = line.splitn(3, ' ');
        let name = parts.next().context("name")?.to_string();
        let file = parts.next().context("file")?;
        let sig = parts.next().context("signature")?;
        let (ins, outs) = sig.split_once(" -> ").context("signature arrow")?;
        let inputs = ins
            .split(';')
            .map(TensorSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let outputs = outs
            .split(';')
            .map(TensorSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let path = dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Artifact {
            name,
            inputs,
            outputs,
            exe,
        })
    }

    pub fn names(&self) -> Vec<&str> {
        let mut n: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        n.sort_unstable();
        n
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .with_context(|| format!("no artifact '{name}' (have: {:?})", self.names()))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute `name` with `inputs`, validating against the manifest
    /// signature, and return the output tensors.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let art = self.artifact(name)?;
        ensure!(
            inputs.len() == art.inputs.len(),
            "{name}: {} inputs given, {} expected",
            inputs.len(),
            art.inputs.len()
        );
        for (i, (t, spec)) in inputs.iter().zip(&art.inputs).enumerate() {
            ensure!(
                t.shape() == spec.shape.as_slice() && t.dtype_name() == spec.dtype,
                "{name}: input {i} is {}{:?}, expected {}{:?}",
                t.dtype_name(),
                t.shape(),
                spec.dtype,
                spec.shape
            );
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(to_literal)
            .collect::<Result<Vec<_>>>()?;
        let result = art.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        ensure!(
            outs.len() == art.outputs.len(),
            "{name}: {} outputs, expected {}",
            outs.len(),
            art.outputs.len()
        );
        outs.iter()
            .zip(&art.outputs)
            .map(|(lit, spec)| from_literal(lit, spec))
            .collect()
    }
}

// PJRT execution tests live in rust/tests/runtime_pjrt.rs (they need
// `make artifacts` to have run).
