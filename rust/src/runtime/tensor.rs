//! Host-side tensor types crossing the PJRT boundary. Pure Rust — built
//! with or without the `xla` feature (the compute passes and their
//! references use these even when the PJRT client is stubbed out).

use anyhow::{bail, Context, Result};

/// A host-side tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v, _) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub(crate) fn dtype_name(&self) -> &'static str {
        match self {
            Tensor::F32(..) => "float32",
            Tensor::I32(..) => "int32",
        }
    }
}

/// Parsed `dtype[d0,d1,...]` from the artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub(crate) fn parse(s: &str) -> Result<Self> {
        let (dtype, rest) = s
            .split_once('[')
            .with_context(|| format!("bad tensor spec '{s}'"))?;
        let dims = rest.strip_suffix(']').context("missing ]")?;
        let shape = if dims.is_empty() {
            vec![]
        } else {
            dims.split(',')
                .map(|d| d.trim().parse::<usize>().map_err(Into::into))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(Self {
            dtype: dtype.to_string(),
            shape,
        })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_parses() {
        let t = TensorSpec::parse("float32[64,1024]").unwrap();
        assert_eq!(t.dtype, "float32");
        assert_eq!(t.shape, vec![64, 1024]);
        assert_eq!(t.elems(), 65536);
        let s = TensorSpec::parse("int32[64]").unwrap();
        assert_eq!(s.shape, vec![64]);
        assert!(TensorSpec::parse("garbage").is_err());
    }

    #[test]
    fn tensor_accessors() {
        let t = Tensor::F32(vec![1.0, 2.0], vec![2]);
        assert_eq!(t.shape(), &[2]);
        assert_eq!(t.len(), 2);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        assert_eq!(t.dtype_name(), "float32");
    }
}
