//! PJRT artifact loading + execution (the `xla` crate wrapper).
//!
//! Built two ways:
//! - `--features xla`: the real PJRT client in `pjrt.rs`;
//! - default (offline): the stub in `stub.rs` with the same API whose
//!   loaders return an error — callers (`gpuvm e2e`, the PJRT tests)
//!   already handle that path gracefully.

pub mod tensor;

#[cfg(feature = "xla")]
pub mod pjrt;

#[cfg(not(feature = "xla"))]
#[path = "stub.rs"]
pub mod pjrt;

pub use pjrt::{Artifact, Runtime};
pub use tensor::{Tensor, TensorSpec};
