//! PJRT artifact loading + execution (the `xla` crate wrapper).

pub mod pjrt;

pub use pjrt::{Artifact, Runtime, Tensor, TensorSpec};
