//! Static per-kernel resource accounting for the Fig 16 register report.
//!
//! The paper's Fig 16 shows registers-per-thread for each benchmark under
//! UVM and under GPUVM, demonstrating that linking the GPUVM runtime into
//! application kernels does not push any of them past the V100's 255
//! usable registers (no spilling). We reproduce that accounting from the
//! kernel descriptors: each app declares its base register footprint
//! (UVM variant ≈ the plain CUDA kernel) and GPUVM adds a fixed runtime
//! cost (page-table walk state, leader-election masks, WR scratch, CQ
//! polling cursor).

use crate::gpu::kernel::KernelResources;

/// The GPUVM runtime's register footprint, derived from the runtime's
/// hot-path state: page number + offset (2), page-table probe (4),
/// `__match_any_sync` masks and leader id (3), WR fields — post number,
/// frame address, host address, rkey, QP id (6), CQ poll state (3),
/// eviction/refcount bookkeeping (4), plus spill-free scratch (4).
pub const GPUVM_RUNTIME_REGISTERS: u32 = 26;

/// One row of the Fig 16 report.
#[derive(Debug, Clone)]
pub struct RegisterRow {
    pub app: String,
    pub uvm: u32,
    pub gpuvm: u32,
    pub spills: bool,
}

/// Build the Fig 16 table from (app name, resources) pairs.
pub fn register_report(apps: &[(&str, KernelResources)]) -> Vec<RegisterRow> {
    apps.iter()
        .map(|(name, r)| RegisterRow {
            app: name.to_string(),
            uvm: r.uvm(),
            gpuvm: r.gpuvm(),
            spills: r.spills(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_shape() {
        let rows = register_report(&[
            (
                "va",
                KernelResources {
                    base_registers: 18,
                    gpuvm_extra_registers: GPUVM_RUNTIME_REGISTERS,
                },
            ),
            (
                "bfs",
                KernelResources {
                    base_registers: 40,
                    gpuvm_extra_registers: GPUVM_RUNTIME_REGISTERS,
                },
            ),
        ]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].gpuvm, 18 + GPUVM_RUNTIME_REGISTERS);
        assert!(rows.iter().all(|r| !r.spills));
        assert!(rows.iter().all(|r| r.gpuvm > r.uvm));
    }
}
