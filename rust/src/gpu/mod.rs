//! GPU execution model: the workload/kernel abstraction, the warp-slot
//! executor, and static resource accounting.

pub mod exec;
pub mod kernel;
pub mod resources;

pub use exec::{run, RunResult};
pub use kernel::{Access, KernelResources, Launch, WarpOp, Workload};
