//! The GPU execution model: warp slots, scheduling, and the main DES loop.
//!
//! Model: the machine exposes `num_gpus × sms × warps_per_sm` hardware
//! warp slots. Logical warps of each kernel launch are assigned to slots;
//! when a logical warp retires, its slot picks up the next one
//! (persistent-warp style). Each runnable warp advances through its
//! `WarpOp` stream; a faulting warp blocks while other warps keep
//! executing — reproducing the latency-hiding dynamics the paper's
//! evaluation depends on. Compute phases and resident-page accesses cost
//! time locally; page faults go through the pluggable
//! [`MemorySystem`](crate::memsys::MemorySystem).

use crate::config::SystemConfig;
use crate::gpu::kernel::{Access, WarpOp, Workload};
use crate::mem::HostMemory;
use crate::memsys::{AccessResult, Ev, MemCtx, MemorySystem, PageAccess, SlotId, Wakes};
use crate::metrics::Metrics;
use crate::sim::{Engine, SimTime};

/// Per-hardware-slot state.
#[derive(Debug, Clone, Copy)]
struct Slot {
    /// Current logical warp, if any.
    logical: Option<usize>,
    /// Pages from the previous access still referenced.
    holding: bool,
    /// When the slot blocked on a fault (for stall accounting).
    blocked_at: Option<SimTime>,
}

/// Outcome of a full workload run.
pub struct RunResult {
    pub metrics: Metrics,
    pub hm: HostMemory,
    /// Kernels launched.
    pub kernels: u64,
    /// DES events processed (simulator perf metric).
    pub events: u64,
}

/// Execute `workload` on the simulated GPU(s) backed by `mem`.
pub fn run(
    cfg: &SystemConfig,
    workload: &mut dyn Workload,
    mem: &mut dyn MemorySystem,
) -> anyhow::Result<RunResult> {
    cfg.validate()?;
    let mut hm = HostMemory::new(cfg.gpuvm.page_size);
    workload.setup(&mut hm);
    let mut m = Metrics::new();
    mem.prepare(&hm, &mut m);

    let slots_per_gpu = cfg.gpu.sms * cfg.gpu.warps_per_sm;
    let total_slots = slots_per_gpu * cfg.gpu.num_gpus;
    let kernel_launch_ns = crate::sim::us(cfg.gpu.kernel_launch_us);

    let mut eng: Engine<Ev> = Engine::new();
    let mut slots = vec![
        Slot {
            logical: None,
            holding: false,
            blocked_at: None,
        };
        total_slots
    ];
    let mut pending: std::collections::VecDeque<usize> = Default::default();
    let mut active = 0usize;
    let mut kernels = 0u64;

    // Launch the first kernel.
    let launched = launch_next(
        workload,
        &mut slots,
        &mut pending,
        &mut active,
        &mut eng,
        0,
        &mut kernels,
    );
    anyhow::ensure!(launched, "workload produced no kernels");

    let mut wakes: Wakes = Vec::new();
    let mut scratch: Vec<PageAccess> = Vec::with_capacity(64);
    loop {
        let Some((now, ev)) = eng.pop() else {
            // Queue empty. If warps are blocked, the memory system may be
            // holding a partial batch — drain it.
            if active > 0 {
                let now = eng.now();
                wakes.clear();
                let progressed = {
                    let mut ctx = MemCtx {
                        now,
                        hm: &mut hm,
                        eng: &mut eng,
                        m: &mut m,
                        wakes: &mut wakes,
                    };
                    mem.drain(&mut ctx)
                };
                schedule_wakes(&mut eng, &mut slots, &mut m, &wakes, now);
                if progressed {
                    continue;
                }
                anyhow::bail!(
                    "deadlock: {active} warps blocked, no events pending \
                     (GPU memory too small for the concurrent working set? \
                     frames={}, active warps={active})",
                    cfg.gpu_frames()
                );
            }
            break;
        };

        match ev {
            Ev::Mem(me) => {
                wakes.clear();
                {
                    let mut ctx = MemCtx {
                        now,
                        hm: &mut hm,
                        eng: &mut eng,
                        m: &mut m,
                        wakes: &mut wakes,
                    };
                    mem.on_event(&mut ctx, me);
                }
                schedule_wakes(&mut eng, &mut slots, &mut m, &wakes, now);
            }
            Ev::Resume { slot } => {
                step_slot(
                    cfg,
                    workload,
                    mem,
                    &mut hm,
                    &mut m,
                    &mut eng,
                    &mut slots,
                    &mut pending,
                    &mut active,
                    slot,
                    now,
                    &mut wakes,
                    &mut scratch,
                );
                // All warps retired → next kernel (if any).
                if active == 0 && pending.is_empty() {
                    launch_next(
                        workload,
                        &mut slots,
                        &mut pending,
                        &mut active,
                        &mut eng,
                        now + kernel_launch_ns,
                        &mut kernels,
                    );
                }
            }
        }
    }

    m.finish_ns = eng.now();
    mem.finalize(&mut m);
    Ok(RunResult {
        metrics: m,
        hm,
        kernels,
        events: eng.processed(),
    })
}

/// Assign the next kernel's logical warps to slots; returns false when the
/// workload is finished.
fn launch_next(
    workload: &mut dyn Workload,
    slots: &mut [Slot],
    pending: &mut std::collections::VecDeque<usize>,
    active: &mut usize,
    eng: &mut Engine<Ev>,
    at: SimTime,
    kernels: &mut u64,
) -> bool {
    let Some(launch) = workload.next_kernel() else {
        return false;
    };
    *kernels += 1;
    debug_assert!(pending.is_empty());
    pending.extend(0..launch.warps);
    for (i, s) in slots.iter_mut().enumerate() {
        debug_assert!(s.logical.is_none());
        if let Some(l) = pending.pop_front() {
            s.logical = Some(l);
            s.holding = false;
            s.blocked_at = None;
            *active += 1;
            eng.schedule(at, Ev::Resume {
                slot: SlotId(i as u32),
            });
        } else {
            break;
        }
    }
    // Zero-warp launches complete immediately; recurse for the next one.
    if launch.warps == 0 {
        return launch_next(workload, slots, pending, active, eng, at, kernels);
    }
    true
}

fn schedule_wakes(
    eng: &mut Engine<Ev>,
    slots: &mut [Slot],
    m: &mut Metrics,
    wakes: &Wakes,
    now: SimTime,
) {
    for &(slot, at) in wakes {
        let s = &mut slots[slot.0 as usize];
        if let Some(b) = s.blocked_at.take() {
            m.stall_ns += at.saturating_sub(b);
        }
        eng.schedule(at.max(now), Ev::Resume { slot });
    }
}

#[allow(clippy::too_many_arguments)]
fn step_slot(
    cfg: &SystemConfig,
    workload: &mut dyn Workload,
    mem: &mut dyn MemorySystem,
    hm: &mut HostMemory,
    m: &mut Metrics,
    eng: &mut Engine<Ev>,
    slots: &mut [Slot],
    pending: &mut std::collections::VecDeque<usize>,
    active: &mut usize,
    slot: SlotId,
    now: SimTime,
    wakes: &mut Wakes,
    scratch: &mut Vec<PageAccess>,
) {
    let si = slot.0 as usize;
    let Some(logical) = slots[si].logical else {
        return; // stale resume for an idle slot
    };

    // Release the previous op's pages (the paper's reference counters:
    // a page is needed until the warp moves past the op that used it).
    if slots[si].holding {
        wakes.clear();
        {
            let mut ctx = MemCtx {
                now,
                hm: &mut *hm,
                eng: &mut *eng,
                m: &mut *m,
                wakes: &mut *wakes,
            };
            mem.release(&mut ctx, slot);
        }
        slots[si].holding = false;
        schedule_wakes(eng, slots, m, wakes, now);
        wakes.clear();
    }

    match workload.next_op(logical) {
        WarpOp::Compute { ops } => {
            let dur = (ops as f64 * cfg.gpu.compute_ns_per_op).ceil() as u64;
            m.compute_ns += dur;
            eng.schedule(now + dur.max(1), Ev::Resume { slot });
        }
        WarpOp::Access(accesses) => {
            let gpu = si / (cfg.gpu.sms * cfg.gpu.warps_per_sm);
            translate_into(hm, &accesses, m, scratch);
            if scratch.is_empty() {
                eng.schedule(now + 1, Ev::Resume { slot });
                return;
            }
            wakes.clear();
            let result = {
                let mut ctx = MemCtx {
                    now,
                    hm: &mut *hm,
                    eng: &mut *eng,
                    m: &mut *m,
                    wakes: &mut *wakes,
                };
                mem.access(&mut ctx, slot, gpu, scratch.as_slice())
            };
            schedule_wakes(eng, slots, m, wakes, now);
            match result {
                AccessResult::Ready { resume_at } => {
                    slots[si].holding = true;
                    eng.schedule(resume_at, Ev::Resume { slot });
                }
                AccessResult::Blocked => {
                    slots[si].holding = true;
                    slots[si].blocked_at = Some(now);
                }
            }
        }
        WarpOp::Done => {
            slots[si].logical = None;
            *active -= 1;
            if let Some(next) = pending.pop_front() {
                slots[si].logical = Some(next);
                *active += 1;
                // Next logical warp starts immediately on this slot.
                eng.schedule(now + 1, Ev::Resume { slot });
            }
        }
    }
}

/// Turn a warp's access groups into a deduplicated page set (into a
/// reused scratch buffer — this runs once per warp op). This is the
/// intra-warp coalescing step (`__match_any_sync` leader election in the
/// paper): 32 lanes touching the same page produce one page reference.
fn translate_into(
    hm: &HostMemory,
    accesses: &[Access],
    m: &mut Metrics,
    pages: &mut Vec<PageAccess>,
) {
    pages.clear();
    let addr = hm.addressing();
    let mut lane_refs = 0u64;
    for acc in accesses {
        m.useful_bytes += acc.useful_bytes();
        let region = acc.region();
        let write = acc.is_write();
        let push_range = |pages: &mut Vec<PageAccess>, start: u64, len: u64| {
            for p in addr.page_range(start, len) {
                let off = p * addr.page_size;
                pages.push(PageAccess {
                    page: hm.page_at(region, off),
                    write,
                });
            }
        };
        match acc {
            Access::Seq { start, len, .. } => {
                lane_refs += 1;
                push_range(pages, *start, *len);
            }
            Access::Strided {
                start,
                stride,
                lanes,
                elem,
                ..
            } => {
                for i in 0..*lanes as u64 {
                    lane_refs += 1;
                    push_range(pages, start + i * stride, *elem);
                }
            }
            Access::Gather { offsets, elem, .. } => {
                for &off in offsets {
                    lane_refs += 1;
                    push_range(pages, off, *elem);
                }
            }
        }
    }
    // Dedup; a page written by any lane is a write.
    pages.sort_by_key(|p| (p.page, !p.write));
    pages.dedup_by(|b, a| {
        if a.page == b.page {
            a.write |= b.write;
            true
        } else {
            false
        }
    });
    m.bump("lane_page_refs", lane_refs);
    m.bump("warp_page_refs", pages.len() as u64);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Test shim over `translate_into`.
    fn translate(hm: &HostMemory, accesses: &[Access], m: &mut Metrics) -> Vec<PageAccess> {
        let mut pages = Vec::new();
        translate_into(hm, accesses, m, &mut pages);
        pages
    }
    use crate::gpu::kernel::Launch;
    use crate::mem::RegionId;
    use crate::memsys::ideal::IdealSystem;

    /// A trivial streaming workload: `warps` warps each do
    /// read-compute-write over one element range, then finish.
    struct Stream {
        warps: usize,
        region: Option<RegionId>,
        launched: bool,
        step: Vec<u8>,
    }

    impl Stream {
        fn new(warps: usize) -> Self {
            Self {
                warps,
                region: None,
                launched: false,
                step: vec![0; warps],
            }
        }
    }

    impl Workload for Stream {
        fn name(&self) -> &str {
            "stream-test"
        }
        fn setup(&mut self, hm: &mut HostMemory) {
            self.region = Some(hm.register("x", (self.warps * 128) as u64));
        }
        fn next_kernel(&mut self) -> Option<Launch> {
            if self.launched {
                return None;
            }
            self.launched = true;
            Some(Launch {
                warps: self.warps,
                tag: 0,
            })
        }
        fn next_op(&mut self, warp: usize) -> WarpOp {
            let s = self.step[warp];
            self.step[warp] += 1;
            match s {
                0 => WarpOp::Access(vec![Access::Seq {
                    region: self.region.unwrap(),
                    start: (warp * 128) as u64,
                    len: 128,
                    write: false,
                }]),
                1 => WarpOp::Compute { ops: 100 },
                _ => WarpOp::Done,
            }
        }
    }

    fn small_cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.gpu.sms = 2;
        cfg.gpu.warps_per_sm = 2;
        cfg.gpu.mem_bytes = 1 << 20;
        cfg
    }

    #[test]
    fn runs_to_completion_on_ideal() {
        let cfg = small_cfg();
        let mut w = Stream::new(16);
        let mut mem = IdealSystem::new(cfg.gpu.hbm_hit_ns);
        let r = run(&cfg, &mut w, &mut mem).unwrap();
        assert_eq!(r.kernels, 1);
        assert!(r.metrics.finish_ns > 0);
        assert_eq!(r.metrics.useful_bytes, 16 * 128);
        // 16 logical warps over 4 slots: 4 rounds of (hit + compute).
        assert!(r.metrics.hits > 0);
    }

    #[test]
    fn more_slots_is_faster() {
        let mut w1 = Stream::new(64);
        let mut w2 = Stream::new(64);
        let mut cfg1 = small_cfg();
        cfg1.gpu.warps_per_sm = 1;
        let mut cfg2 = small_cfg();
        cfg2.gpu.warps_per_sm = 16;
        let r1 = run(&cfg1, &mut w1, &mut IdealSystem::new(400)).unwrap();
        let r2 = run(&cfg2, &mut w2, &mut IdealSystem::new(400)).unwrap();
        assert!(
            r2.metrics.finish_ns < r1.metrics.finish_ns,
            "{} !< {}",
            r2.metrics.finish_ns,
            r1.metrics.finish_ns
        );
    }

    #[test]
    fn translate_dedups_within_page() {
        let mut hm = HostMemory::new(4096);
        let r = hm.register("x", 1 << 20);
        let mut m = Metrics::new();
        // 32 lanes × 4 bytes stride 4 = all in one page.
        let pages = translate(
            &hm,
            &[Access::Strided {
                region: r,
                start: 0,
                stride: 4,
                lanes: 32,
                elem: 4,
                write: false,
            }],
            &mut m,
        );
        assert_eq!(pages.len(), 1);
        assert_eq!(m.counter("lane_page_refs"), 32);
        assert_eq!(m.counter("warp_page_refs"), 1);
    }

    #[test]
    fn translate_strided_hits_many_pages() {
        let mut hm = HostMemory::new(4096);
        let r = hm.register("x", 1 << 20);
        let mut m = Metrics::new();
        // Column access: each lane in its own page.
        let pages = translate(
            &hm,
            &[Access::Strided {
                region: r,
                start: 0,
                stride: 4096,
                lanes: 32,
                elem: 4,
                write: false,
            }],
            &mut m,
        );
        assert_eq!(pages.len(), 32);
    }

    #[test]
    fn translate_write_wins_on_dedup() {
        let mut hm = HostMemory::new(4096);
        let r = hm.register("x", 8192);
        let mut m = Metrics::new();
        let pages = translate(
            &hm,
            &[
                Access::Seq {
                    region: r,
                    start: 0,
                    len: 64,
                    write: false,
                },
                Access::Seq {
                    region: r,
                    start: 64,
                    len: 64,
                    write: true,
                },
            ],
            &mut m,
        );
        assert_eq!(pages.len(), 1);
        assert!(pages[0].write);
    }

    #[test]
    fn multi_kernel_workload() {
        struct TwoKernels {
            region: Option<RegionId>,
            kernel: u32,
            step: u8,
        }
        impl Workload for TwoKernels {
            fn name(&self) -> &str {
                "two"
            }
            fn setup(&mut self, hm: &mut HostMemory) {
                self.region = Some(hm.register("x", 4096));
            }
            fn next_kernel(&mut self) -> Option<Launch> {
                self.kernel += 1;
                self.step = 0;
                (self.kernel <= 2).then_some(Launch { warps: 1, tag: 0 })
            }
            fn next_op(&mut self, _w: usize) -> WarpOp {
                self.step += 1;
                if self.step == 1 {
                    WarpOp::Compute { ops: 10 }
                } else {
                    WarpOp::Done
                }
            }
        }
        let cfg = small_cfg();
        let mut w = TwoKernels {
            region: None,
            kernel: 0,
            step: 0,
        };
        let r = run(&cfg, &mut w, &mut IdealSystem::new(400)).unwrap();
        assert_eq!(r.kernels, 2);
    }
}
