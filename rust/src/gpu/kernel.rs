//! The workload abstraction: GPU kernels as per-warp access/compute
//! streams over `gpuvm<T>`-style arrays (paper Listing 1).
//!
//! Applications do not simulate individual instructions; they emit, per
//! warp, the sequence of *memory access groups* and *compute phases* the
//! real kernel would perform. The executor (gpu::exec) translates access
//! groups into page sets — which is exactly where the paper's intra-warp
//! `__match_any_sync` coalescing happens — and drives them through a
//! pluggable memory system (GPUVM, UVM, or ideal/bulk).

use crate::mem::RegionId;

/// One warp-level memory access group (the 32 lanes' addresses issued
/// together). Offsets are in bytes within the region.
#[derive(Debug, Clone)]
pub enum Access {
    /// Coalesced: lanes read/write `[start, start+len)` contiguously.
    Seq {
        region: RegionId,
        start: u64,
        len: u64,
        write: bool,
    },
    /// Strided (column-major matrix walks — MVT/ATAX/BIGC): lane `i`
    /// touches `elem` bytes at `start + i*stride`, for `lanes` lanes.
    Strided {
        region: RegionId,
        start: u64,
        stride: u64,
        lanes: u32,
        elem: u64,
        write: bool,
    },
    /// Irregular gather/scatter (graph neighbor lists, sparse queries):
    /// each listed byte offset touches `elem` bytes.
    Gather {
        region: RegionId,
        offsets: Vec<u64>,
        elem: u64,
        write: bool,
    },
}

impl Access {
    /// Bytes the application actually consumes from this access (the
    /// numerator of the I/O-amplification metric).
    pub fn useful_bytes(&self) -> u64 {
        match self {
            Access::Seq { len, .. } => *len,
            Access::Strided { lanes, elem, .. } => *lanes as u64 * *elem,
            Access::Gather { offsets, elem, .. } => offsets.len() as u64 * *elem,
        }
    }

    pub fn is_write(&self) -> bool {
        match self {
            Access::Seq { write, .. }
            | Access::Strided { write, .. }
            | Access::Gather { write, .. } => *write,
        }
    }

    pub fn region(&self) -> RegionId {
        match self {
            Access::Seq { region, .. }
            | Access::Strided { region, .. }
            | Access::Gather { region, .. } => *region,
        }
    }
}

/// One step of a warp's instruction stream.
#[derive(Debug, Clone)]
pub enum WarpOp {
    /// Issue these access groups together; the warp blocks until all
    /// touched pages are resident.
    Access(Vec<Access>),
    /// Arithmetic phase: `ops` per-lane operations (scaled to time by
    /// `GpuConfig::compute_ns_per_op`).
    Compute { ops: u64 },
    /// This warp has retired (its slot picks up the next logical warp).
    Done,
}

/// Static per-kernel resource usage, for the Fig 16 register report.
/// `base` is the application kernel alone (the UVM variant); GPUVM's
/// runtime adds `gpuvm_extra` registers for page-table walks, leader
/// election state, WR construction and CQ polling.
#[derive(Debug, Clone, Copy)]
pub struct KernelResources {
    pub base_registers: u32,
    pub gpuvm_extra_registers: u32,
}

impl KernelResources {
    pub fn uvm(&self) -> u32 {
        self.base_registers
    }
    pub fn gpuvm(&self) -> u32 {
        self.base_registers + self.gpuvm_extra_registers
    }
    /// V100: 255 usable registers per thread before spilling.
    pub fn spills(&self) -> bool {
        self.gpuvm() > 255
    }
}

/// A kernel launch: how many logical warps the grid contains.
#[derive(Debug, Clone, Copy)]
pub struct Launch {
    pub warps: usize,
    /// Optional label for metrics/tracing (e.g. "bfs-level-3").
    pub tag: u32,
}

/// A workload is a sequence of kernel launches (graph apps relaunch per
/// iteration) whose warps emit `WarpOp`s on demand.
pub trait Workload {
    fn name(&self) -> &str;

    /// Register the application's arrays in host memory. Called once.
    fn setup(&mut self, hm: &mut crate::mem::HostMemory);

    /// Launch the next kernel, or `None` when the application finished.
    /// The first call launches the first kernel.
    fn next_kernel(&mut self) -> Option<Launch>;

    /// Next op for `warp` (0-based within the current launch). Called
    /// repeatedly until it returns `WarpOp::Done` for that warp.
    fn next_op(&mut self, warp: usize) -> WarpOp;

    /// Resource descriptor for the Fig 16 report.
    fn resources(&self) -> KernelResources {
        KernelResources {
            base_registers: 32,
            gpuvm_extra_registers: 24,
        }
    }

    /// Regions eligible for `cudaMemAdviseSetReadMostly` — the app's
    /// read-only inputs (the paper's UVM "wm" configuration). Only valid
    /// after `setup`. Default: none.
    fn read_mostly_regions(&self) -> Vec<RegionId> {
        Vec::new()
    }
}

/// Delegation so wrappers (e.g. [`crate::apps::Advised`]) can hold
/// either an owned workload or a caller's `&mut dyn Workload`.
impl<W: Workload + ?Sized> Workload for &mut W {
    fn name(&self) -> &str {
        (**self).name()
    }
    fn setup(&mut self, hm: &mut crate::mem::HostMemory) {
        (**self).setup(hm)
    }
    fn next_kernel(&mut self) -> Option<Launch> {
        (**self).next_kernel()
    }
    fn next_op(&mut self, warp: usize) -> WarpOp {
        (**self).next_op(warp)
    }
    fn resources(&self) -> KernelResources {
        (**self).resources()
    }
    fn read_mostly_regions(&self) -> Vec<RegionId> {
        (**self).read_mostly_regions()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn useful_bytes() {
        let seq = Access::Seq {
            region: RegionId(0),
            start: 0,
            len: 128,
            write: false,
        };
        assert_eq!(seq.useful_bytes(), 128);
        let st = Access::Strided {
            region: RegionId(0),
            start: 0,
            stride: 4096,
            lanes: 32,
            elem: 4,
            write: true,
        };
        assert_eq!(st.useful_bytes(), 128);
        assert!(st.is_write());
        let g = Access::Gather {
            region: RegionId(1),
            offsets: vec![0, 8, 4096],
            elem: 8,
            write: false,
        };
        assert_eq!(g.useful_bytes(), 24);
        assert_eq!(g.region(), RegionId(1));
    }

    #[test]
    fn resources_spill_threshold() {
        let r = KernelResources {
            base_registers: 40,
            gpuvm_extra_registers: 26,
        };
        assert_eq!(r.uvm(), 40);
        assert_eq!(r.gpuvm(), 66);
        assert!(!r.spills());
        let big = KernelResources {
            base_registers: 240,
            gpuvm_extra_registers: 26,
        };
        assert!(big.spills());
    }
}
