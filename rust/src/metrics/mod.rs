//! Run metrics: counters, latency histograms, link utilization, and the
//! I/O-amplification accounting Fig 12/15 report.

use crate::fabric::TransportStats;
use crate::sim::SimTime;
use crate::util::stats::LatencyHist;
use std::collections::BTreeMap;

/// Everything a single simulated run records. Memory systems and the GPU
/// execution model write into this; benches and the CLI read it out.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Total page faults taken (leader-level, post-coalescing for GPUVM;
    /// fault groups for UVM).
    pub faults: u64,
    /// Faults resolved by joining an already-in-flight fault (inter-warp
    /// coalescing for GPUVM; duplicate-fault squash for UVM).
    pub coalesced_faults: u64,
    /// Page-table hits (access found the page resident).
    pub hits: u64,
    /// Bytes moved host→GPU.
    pub bytes_in: u64,
    /// Bytes moved GPU→host (write-backs).
    pub bytes_out: u64,
    /// Bytes transferred that the application actually read/wrote.
    pub useful_bytes: u64,
    /// Pages evicted.
    pub evictions: u64,
    /// Evictions of clean pages (no write-back; `evictions_clean +
    /// evictions_dirty == evictions`).
    pub evictions_clean: u64,
    /// Evictions of dirty pages — each one writes `page/group` bytes
    /// back (`bytes_out` is the write-back byte total).
    pub evictions_dirty: u64,
    /// UVM-only: evictions forced through a nonzero reference count
    /// (the driver unmaps pages GPU threads are actively touching; they
    /// refault and replay — thrash, not deadlock).
    pub evictions_forced: u64,
    /// Evictions that had to wait for a nonzero reference count.
    pub eviction_waits: u64,
    /// Pages that were evicted and later re-fetched (redundant transfer).
    pub refetches: u64,
    /// Refetches of pages evicted within the last
    /// [`crate::residency::THRASH_WINDOW`] fills — the thrash
    /// indicator: the policy threw out the working set.
    pub thrash_refetches: u64,
    /// Reuse distance of refetched pages, in *fills* between eviction
    /// and refault (log2 buckets; not nanoseconds).
    pub reuse_distance: LatencyHist,
    /// Speculative transfer units issued by the prefetch policy
    /// (GPUVM: extra pages posted to the RNIC; UVM: ride-along group
    /// pages for `fixed`, speculative fault-buffer entries otherwise).
    pub prefetched_pages: u64,
    /// Prefetched pages later touched by the application
    /// (prefetched-then-used; always ≤ `prefetched_pages`).
    pub prefetch_hits: u64,
    /// Prefetched pages evicted without ever being touched
    /// (`prefetch_hits + prefetch_wasted ≤ prefetched_pages`).
    pub prefetch_wasted: u64,
    /// Doorbell rings.
    pub doorbells: u64,
    /// Work requests posted to RNIC queues.
    pub work_requests: u64,
    /// Fault service latency (post→data-resident), ns.
    pub fault_latency: LatencyHist,
    /// Lifecycle-stage decomposition of `fault_latency`
    /// ([`crate::obs::stage_split`]): fault→WR-post (doorbell batching /
    /// driver queueing), WR-post→completion (transfer), and
    /// completion→mapped (fill). Same population as `fault_latency`.
    pub stage_queue: LatencyHist,
    pub stage_transfer: LatencyHist,
    pub stage_fill: LatencyHist,
    /// Fill→waiter-release hop (GPUVM: CQ poll; UVM: µTLB re-hit).
    /// Measured per serviced fault but *excluded* from the latency sum —
    /// `fault_latency` ends at fill, and so must the stage total.
    pub stage_wake: LatencyHist,
    /// Exact integer stage totals, ns (histogram means are floats; the
    /// span-reconciliation property needs bit-for-bit sums). Invariant:
    /// `stage_queue_ns + stage_transfer_ns + stage_fill_ns ==
    /// fault_service_ns ==` the exact sum of every latency recorded
    /// into `fault_latency`.
    pub stage_queue_ns: u64,
    pub stage_transfer_ns: u64,
    pub stage_fill_ns: u64,
    pub fault_service_ns: u64,
    /// Interval samples taken by the attached [`crate::obs::Sampler`]
    /// (0 when obs is off). In the fingerprint so identical runs must
    /// sample identically.
    pub obs_samples: u64,
    /// Per-warp stall time waiting on faults, ns (summed).
    pub stall_ns: u64,
    /// Compute time summed over warps, ns.
    pub compute_ns: u64,
    /// End of run, ns.
    pub finish_ns: SimTime,
    /// Per-link busy nanoseconds (keyed by link name) for utilization.
    pub link_busy_ns: BTreeMap<String, u64>,
    /// Page-migration engine accounting (doorbells, WRs, bytes, per-NIC
    /// breakdown), exported by the memory system's `finalize`.
    pub transport: TransportStats,
    /// One-time setup cost reported separately (e.g. memadvise), ns.
    pub setup_ns: u64,
    /// Extra named counters (ablations, per-app detail).
    pub counters: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn bump(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn add_link_busy(&mut self, link: &str, ns: u64) {
        *self.link_busy_ns.entry(link.to_string()).or_insert(0) += ns;
    }

    /// Achieved host→GPU throughput over the run, bytes/s.
    pub fn throughput_in(&self) -> f64 {
        if self.finish_ns == 0 {
            return 0.0;
        }
        self.bytes_in as f64 / (self.finish_ns as f64 / 1e9)
    }

    /// Utilization of a link over the run duration, in [0, 1].
    pub fn link_utilization(&self, link: &str) -> f64 {
        if self.finish_ns == 0 {
            return 0.0;
        }
        let busy = self.link_busy_ns.get(link).copied().unwrap_or(0);
        (busy as f64 / self.finish_ns as f64).min(1.0)
    }

    /// I/O amplification: bytes moved per byte the application needed.
    /// 1.0 is perfect; UVM's 64 KB granularity on sparse access inflates it.
    pub fn io_amplification(&self) -> f64 {
        if self.useful_bytes == 0 {
            return 0.0;
        }
        (self.bytes_in + self.bytes_out) as f64 / self.useful_bytes as f64
    }

    /// Prefetch accuracy so far: prefetched-then-used over issued.
    /// (Pages still resident and untouched count against accuracy.)
    pub fn prefetch_accuracy(&self) -> f64 {
        if self.prefetched_pages == 0 {
            return 0.0;
        }
        self.prefetch_hits as f64 / self.prefetched_pages as f64
    }

    /// Fault hit rate = hits / (hits + faults + coalesced).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.faults + self.coalesced_faults;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    /// Merge another run's metrics (used by multi-GPU aggregation).
    pub fn merge(&mut self, other: &Metrics) {
        self.faults += other.faults;
        self.coalesced_faults += other.coalesced_faults;
        self.hits += other.hits;
        self.bytes_in += other.bytes_in;
        self.bytes_out += other.bytes_out;
        self.useful_bytes += other.useful_bytes;
        self.evictions += other.evictions;
        self.evictions_clean += other.evictions_clean;
        self.evictions_dirty += other.evictions_dirty;
        self.evictions_forced += other.evictions_forced;
        self.eviction_waits += other.eviction_waits;
        self.refetches += other.refetches;
        self.thrash_refetches += other.thrash_refetches;
        self.fault_latency.merge(&other.fault_latency);
        self.stage_queue.merge(&other.stage_queue);
        self.stage_transfer.merge(&other.stage_transfer);
        self.stage_fill.merge(&other.stage_fill);
        self.stage_wake.merge(&other.stage_wake);
        self.stage_queue_ns += other.stage_queue_ns;
        self.stage_transfer_ns += other.stage_transfer_ns;
        self.stage_fill_ns += other.stage_fill_ns;
        self.fault_service_ns += other.fault_service_ns;
        self.obs_samples += other.obs_samples;
        self.reuse_distance.merge(&other.reuse_distance);
        self.prefetched_pages += other.prefetched_pages;
        self.prefetch_hits += other.prefetch_hits;
        self.prefetch_wasted += other.prefetch_wasted;
        self.doorbells += other.doorbells;
        self.work_requests += other.work_requests;
        self.stall_ns += other.stall_ns;
        self.compute_ns += other.compute_ns;
        self.finish_ns = self.finish_ns.max(other.finish_ns);
        self.setup_ns += other.setup_ns;
        self.transport.merge(&other.transport);
        for (k, v) in &other.link_busy_ns {
            *self.link_busy_ns.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
    }

    /// Canonical deterministic counters, as (name, value) pairs in a
    /// fixed order — the equality the trace-conformance harness
    /// ([`crate::trace`]) asserts alongside event-stream identity, and
    /// the invariant the determinism certifier
    /// ([`crate::analyze::perturb`]) proves stable under bounded
    /// schedule perturbation. Only integer counters that are
    /// bit-reproducible across identical runs belong here (histogram
    /// means and derived floats are excluded).
    pub fn fingerprint(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("finish_ns", self.finish_ns),
            ("faults", self.faults),
            ("coalesced_faults", self.coalesced_faults),
            ("hits", self.hits),
            ("bytes_in", self.bytes_in),
            ("bytes_out", self.bytes_out),
            ("useful_bytes", self.useful_bytes),
            ("evictions", self.evictions),
            ("evictions_clean", self.evictions_clean),
            ("evictions_dirty", self.evictions_dirty),
            ("evictions_forced", self.evictions_forced),
            ("eviction_waits", self.eviction_waits),
            ("refetches", self.refetches),
            ("thrash_refetches", self.thrash_refetches),
            ("prefetched_pages", self.prefetched_pages),
            ("prefetch_hits", self.prefetch_hits),
            ("prefetch_wasted", self.prefetch_wasted),
            ("doorbells", self.doorbells),
            ("work_requests", self.work_requests),
            ("fault_latency_count", self.fault_latency.count()),
            ("reuse_distance_count", self.reuse_distance.count()),
            ("stage_queue_ns", self.stage_queue_ns),
            ("stage_transfer_ns", self.stage_transfer_ns),
            ("stage_fill_ns", self.stage_fill_ns),
            ("fault_service_ns", self.fault_service_ns),
            ("obs_samples", self.obs_samples),
        ]
    }

    /// Record one serviced demand fault's stage decomposition
    /// (`stages` from [`crate::obs::stage_split`], `wake` the
    /// fill→release hop). Keeps the histograms and the exact integer
    /// totals in lockstep; callers record into `fault_latency`
    /// separately (it predates this breakdown and some systems record
    /// it on paths with no stage attribution).
    pub fn record_stages(&mut self, stages: [u64; 3], wake: u64) {
        self.stage_queue.record(stages[0]);
        self.stage_transfer.record(stages[1]);
        self.stage_fill.record(stages[2]);
        self.stage_wake.record(wake);
        self.stage_queue_ns += stages[0];
        self.stage_transfer_ns += stages[1];
        self.stage_fill_ns += stages[2];
        self.fault_service_ns += stages[0] + stages[1] + stages[2];
    }

    /// Counters that must agree with a captured trace's event counts,
    /// as `(event-kind name, expected count)` pairs — the bridge the
    /// protocol analyzer ([`crate::analyze::lint::metrics_mismatches`])
    /// checks between the metrics ledger and the event stream. Only
    /// kinds recorded one-to-one with a counter belong here (fills are
    /// excluded: `bytes_in` counts bytes, not fill events).
    pub fn trace_expectations(&self) -> [(&'static str, u64); 5] {
        [
            ("fault", self.faults),
            ("evict-clean", self.evictions_clean),
            ("evict-dirty", self.evictions_dirty),
            ("evict-forced", self.evictions_forced),
            ("wr-post", self.work_requests),
        ]
    }

    /// Compact single-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "t={} faults={} coalesced={} hits={} in={} out={} evict={} refetch={} amp={:.2} bw_in={}",
            crate::util::bench::fmt_ns(self.finish_ns),
            self.faults,
            self.coalesced_faults,
            self.hits,
            crate::util::bench::fmt_bytes(self.bytes_in),
            crate::util::bench::fmt_bytes(self.bytes_out),
            self.evictions,
            self.refetches,
            self.io_amplification(),
            crate::util::bench::fmt_gbps(self.throughput_in()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_and_amplification() {
        let mut m = Metrics::new();
        m.bytes_in = 12_000_000_000;
        m.useful_bytes = 6_000_000_000;
        m.finish_ns = 1_000_000_000; // 1 s
        assert!((m.throughput_in() - 12e9).abs() < 1.0);
        assert!((m.io_amplification() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn link_utilization_bounded() {
        let mut m = Metrics::new();
        m.finish_ns = 100;
        m.add_link_busy("nic0", 250);
        assert_eq!(m.link_utilization("nic0"), 1.0);
        assert_eq!(m.link_utilization("absent"), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics::new();
        a.faults = 5;
        a.finish_ns = 10;
        a.bump("x", 1);
        a.reuse_distance.record(4);
        let mut b = Metrics::new();
        b.faults = 7;
        b.finish_ns = 20;
        b.bump("x", 2);
        b.reuse_distance.record(16);
        b.fault_latency.record(1000);
        b.record_stages([100, 800, 0], 50);
        a.merge(&b);
        assert_eq!(a.faults, 12);
        assert_eq!(a.finish_ns, 20);
        assert_eq!(a.counter("x"), 3);
        // Histograms fold in too (multi-GPU aggregation keeps telemetry).
        assert_eq!(a.reuse_distance.count(), 2);
        assert_eq!(a.fault_latency.count(), 1);
        assert!((a.reuse_distance.mean_ns() - 10.0).abs() < 1e-9);
        // Stage breakdowns merge without dilution: histograms and exact
        // totals both carry over.
        assert_eq!(a.stage_queue.count(), 1);
        assert_eq!(a.stage_wake.count(), 1);
        assert_eq!(a.stage_queue_ns, 100);
        assert_eq!(a.stage_transfer_ns, 800);
        assert_eq!(a.fault_service_ns, 900);
    }

    #[test]
    fn record_stages_keeps_exact_totals_in_lockstep() {
        let mut m = Metrics::new();
        m.record_stages([10, 20, 0], 5);
        m.record_stages([0, 70, 30], 5);
        assert_eq!(m.stage_queue_ns + m.stage_transfer_ns + m.stage_fill_ns, m.fault_service_ns);
        assert_eq!(m.fault_service_ns, 130);
        assert_eq!(m.stage_queue.count(), 2);
        assert_eq!(m.stage_transfer.count(), 2);
        assert_eq!(m.stage_fill.count(), 2);
        assert_eq!(m.stage_wake.count(), 2);
    }

    #[test]
    fn fingerprint_tracks_deterministic_counters() {
        let mut m = Metrics::new();
        m.faults = 3;
        m.bytes_in = 4096;
        m.fault_latency.record(100);
        let fp = m.fingerprint();
        let get = |k: &str| fp.iter().find(|(n, _)| *n == k).unwrap().1;
        assert_eq!(get("faults"), 3);
        assert_eq!(get("bytes_in"), 4096);
        assert_eq!(get("fault_latency_count"), 1);
        // Equal metrics → equal fingerprints; a drifted counter shows.
        let mut m2 = m.clone();
        assert_eq!(m.fingerprint(), m2.fingerprint());
        m2.evictions += 1;
        assert_ne!(m.fingerprint(), m2.fingerprint());
        // Stage totals and sampling activity are fingerprinted too.
        let mut m3 = m.clone();
        m3.record_stages([1, 2, 3], 0);
        assert_ne!(m.fingerprint(), m3.fingerprint());
        let mut m4 = m.clone();
        m4.obs_samples += 1;
        assert_ne!(m.fingerprint(), m4.fingerprint());
    }

    #[test]
    fn empty_metrics_safe() {
        let m = Metrics::new();
        assert_eq!(m.throughput_in(), 0.0);
        assert_eq!(m.io_amplification(), 0.0);
        assert_eq!(m.hit_rate(), 0.0);
        assert!(!m.summary().is_empty());
    }
}
